//! Block-quantized tensors with dequant-on-the-fly matmul kernels.
//!
//! The serve path shares ONE frozen backbone across N adapters
//! (`model::Backbone`); those tensors never see a gradient, which makes
//! them the ideal quantization target (the QLoRA recipe: low-bit frozen
//! base, full-precision adapters). This module provides the storage type
//! ([`QuantMat`]) and the fused kernels; `model::SharedMat` dispatches
//! between it and plain f32 storage per the `[model] backbone_dtype`
//! config key.
//!
//! # Block layout (int8, symmetric)
//!
//! A `rows × cols` matrix is cut into blocks of [`QUANT_BLOCK`] = 64
//! **consecutive row-major elements**; every row starts a new block, so a
//! row owns `ceil(cols / 64)` blocks and blocks never straddle rows. Per
//! block one f32 scale `s = absmax / 127` is stored (zero-point is fixed
//! at 0 — the layout byte reserves asymmetric variants for later); each
//! element is stored as `q = round(x / s)` in `[-127, 127]` (`-128` is
//! unused). Dequantization is `x̂ = s · q`, computed by one shared
//! [`dq`] helper so every code path — simple kernel, tiled pack step,
//! row gather — produces bit-identical dequantized values.
//!
//! Byte-by-byte, a `QuantMat` is (all little-endian, as serialized by
//! `Backbone`-level consumers; in memory the two arrays are separate):
//!
//! ```text
//! q:      rows*cols bytes   int8 codes, row-major, one byte per element
//! scales: rows*ceil(cols/64)*4 bytes   f32 LE, one per block, row-major
//! ```
//!
//! so storage is `1 + 4/64 ≈ 1.0625` bytes/element vs 4 for f32 — a
//! 3.76× shrink (the bench gate pins the ratio ≤ 0.35).
//!
//! # Error budget
//!
//! With `s = absmax/127` and round-to-nearest, `|x/s − q| ≤ 1/2` for
//! every in-block element (no clamping can occur: `|x|/s ≤ 127`), so the
//! per-element reconstruction error is bounded by
//!
//! ```text
//! |x − x̂| ≤ s/2 = absmax(block) / 254
//! ```
//!
//! An all-zero block stores `s = 0` and round-trips exactly.
//! `tests/quant.rs` pins this budget for f32 and f64.
//!
//! # Accumulation-order policy
//!
//! These kernels inherit the PR 6 contract from `linalg::matmul`
//! verbatim: every C element accumulates in ascending shared-dimension
//! (`k`) order, k-blocks are visited ascending, and the `nt` family
//! keeps dot-then-add semantics (a zeroed register tile accumulated over
//! the full `k` range, added to C once). Dequantization happens in the
//! packed-B-panel step — the scale multiply is applied while filling the
//! scratch panel — so the MR=4 micro-kernel inner loops are byte-for-byte
//! the ones f32 uses ([`matmul`]'s `nn_micro`), and tiled == simple ==
//! threaded remains **bit-identical** for quantized operands too
//! (decode-shape `[1, k]` products bit-match batched prefill rows for
//! int8 backbones exactly as for f32). When quantization is off the f32
//! path is untouched: nothing in `matmul` changed numerically.
//!
//! [`matmul`]: super::matmul

use super::matmul::{nn_micro, run_row_panels, threads_for, SendPtr, KC, MR, NC};
use super::matmul::{TILE_MIN_FLOPS, TILE_MIN_ROWS};
use super::matrix::{Matrix, Scalar};

/// Elements per quantization block (consecutive, row-major, never
/// straddling a row boundary). 64 divides the matmul column-block width
/// `NC = 128`, so a packed panel always starts on a block boundary.
pub const QUANT_BLOCK: usize = 64;

/// Block-quantized dense matrix: int8 codes plus one scale per
/// [`QUANT_BLOCK`]-element block. See the module docs for the layout and
/// error budget.
#[derive(Clone, PartialEq)]
pub struct QuantMatrix<T: Scalar> {
    pub rows: usize,
    pub cols: usize,
    /// Row-major int8 codes, `rows * cols` entries.
    pub q: Vec<i8>,
    /// Per-block scales, `rows * ceil(cols / QUANT_BLOCK)` entries.
    pub scales: Vec<T>,
}

/// f32-scaled quantized matrix — the backbone storage type.
pub type QuantMat = QuantMatrix<f32>;
/// f64-scaled quantized matrix — used by the error-budget tests.
pub type QuantDMat = QuantMatrix<f64>;

/// The one dequantization formula. Every kernel and row gather goes
/// through this so all paths reconstruct bit-identical values.
#[inline(always)]
fn dq<T: Scalar>(s: T, q: i8) -> T {
    s * T::from_f64(q as f64)
}

impl<T: Scalar> QuantMatrix<T> {
    /// Symmetric per-block quantization of a dense matrix.
    pub fn quantize(m: &Matrix<T>) -> Self {
        let bpr = m.cols.div_ceil(QUANT_BLOCK);
        let mut q = vec![0i8; m.rows * m.cols];
        let mut scales = vec![T::ZERO; m.rows * bpr];
        for i in 0..m.rows {
            let row = &m.data[i * m.cols..(i + 1) * m.cols];
            let qrow = &mut q[i * m.cols..(i + 1) * m.cols];
            for (blk, (src, dst)) in
                row.chunks(QUANT_BLOCK).zip(qrow.chunks_mut(QUANT_BLOCK)).enumerate()
            {
                let mut absmax = 0f64;
                for &v in src {
                    absmax = absmax.max(v.abs().to_f64());
                }
                if absmax == 0.0 {
                    continue; // scale 0, codes 0: exact round-trip
                }
                let s = absmax / 127.0;
                scales[i * bpr + blk] = T::from_f64(s);
                for (d, &v) in dst.iter_mut().zip(src) {
                    *d = (v.to_f64() / s).round().clamp(-127.0, 127.0) as i8;
                }
            }
        }
        Self { rows: m.rows, cols: m.cols, q, scales }
    }

    /// Dense reconstruction `x̂ = s·q` (test/debug surface; the serving
    /// kernels dequantize on the fly instead).
    pub fn dequantize(&self) -> Matrix<T> {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (lo, hi) = (i * self.cols, (i + 1) * self.cols);
            dequant_segment(&self.q[lo..hi], self.scales_row(i), 0, &mut m.data[lo..hi]);
        }
        m
    }

    /// Blocks per row.
    #[inline]
    pub fn blocks_per_row(&self) -> usize {
        self.cols.div_ceil(QUANT_BLOCK)
    }

    #[inline]
    fn scales_row(&self, i: usize) -> &[T] {
        let bpr = self.blocks_per_row();
        &self.scales[i * bpr..(i + 1) * bpr]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident bytes of the quantized payload (codes + scales).
    pub fn bytes(&self) -> usize {
        self.q.len() + self.scales.len() * std::mem::size_of::<T>()
    }

    /// Dequantize row `i` into `out` (`out = x̂ᵢ`).
    pub fn dequant_row_into(&self, i: usize, out: &mut [T]) {
        assert_eq!(out.len(), self.cols);
        dequant_segment(&self.q[i * self.cols..(i + 1) * self.cols], self.scales_row(i), 0, out);
    }

    /// Accumulate row `i` into `out` (`out += x̂ᵢ`) — the embedding
    /// gather `tok + pos` path.
    pub fn add_row_into(&self, i: usize, out: &mut [T]) {
        assert_eq!(out.len(), self.cols);
        let qrow = &self.q[i * self.cols..(i + 1) * self.cols];
        let srow = self.scales_row(i);
        for (blk, (qchunk, ochunk)) in
            qrow.chunks(QUANT_BLOCK).zip(out.chunks_mut(QUANT_BLOCK)).enumerate()
        {
            let s = srow[blk];
            for (o, &qv) in ochunk.iter_mut().zip(qchunk) {
                *o += dq(s, qv);
            }
        }
    }
}

/// Dequantize the code segment starting at column `col0` of a row into
/// `dst` (`dst[j] = dq(scale(col0 + j), qseg[j])`). Shared by the dense
/// reconstruction, the row gather and the tiled pack step.
fn dequant_segment<T: Scalar>(qseg: &[i8], srow: &[T], col0: usize, dst: &mut [T]) {
    let mut j = 0;
    while j < qseg.len() {
        let blk = (col0 + j) / QUANT_BLOCK;
        let end = ((blk + 1) * QUANT_BLOCK - col0).min(qseg.len());
        let s = srow[blk];
        for jj in j..end {
            dst[jj] = dq(s, qseg[jj]);
        }
        j = end;
    }
}

// ---------------------------------------------------------------------------
// Panel kernels: C += A @ Ŵ (nn) and C += A @ Ŵᵀ (nt)
// ---------------------------------------------------------------------------

/// Plain i-k-j kernel with inline dequant: C += A @ Ŵ over a row panel.
/// Same ascending-k order as `matmul`'s `nn_simple`; the B element is
/// `dq(s, q)`, exactly what the tiled pack step writes.
fn qnn_simple<T: Scalar>(a: &[T], k: usize, w: &QuantMatrix<T>, c: &mut [T]) {
    let n = w.cols;
    let bpr = w.blocks_per_row();
    for (a_row, c_row) in a.chunks_exact(k).zip(c.chunks_exact_mut(n)) {
        for (kk, &a_ik) in a_row.iter().enumerate() {
            let qrow = &w.q[kk * n..(kk + 1) * n];
            let srow = &w.scales[kk * bpr..(kk + 1) * bpr];
            for (blk, (qchunk, cchunk)) in
                qrow.chunks(QUANT_BLOCK).zip(c_row.chunks_mut(QUANT_BLOCK)).enumerate()
            {
                let s = srow[blk];
                for (c_v, &qv) in cchunk.iter_mut().zip(qchunk) {
                    *c_v += a_ik * dq(s, qv);
                }
            }
        }
    }
}

/// Tiled kernel: C += A @ Ŵ. Identical structure to `matmul`'s
/// `nn_tiled` except the pack step dequantizes while filling the scratch
/// panel — the `nn_micro` inner loops then run unmodified on f32/f64.
fn qnn_tiled<T: Scalar>(a: &[T], k: usize, w: &QuantMatrix<T>, c: &mut [T], pack: &mut [T]) {
    let n = w.cols;
    let bpr = w.blocks_per_row();
    for kc in (0..k).step_by(KC) {
        let kb = KC.min(k - kc);
        for jc in (0..n).step_by(NC) {
            let jb = NC.min(n - jc);
            // Pack + dequantize the kb×jb block of Ŵ (rows of width jb).
            for kk in 0..kb {
                let row = kc + kk;
                let qseg = &w.q[row * n + jc..row * n + jc + jb];
                let srow = &w.scales[row * bpr..(row + 1) * bpr];
                dequant_segment(qseg, srow, jc, &mut pack[kk * jb..(kk + 1) * jb]);
            }
            let packed = &pack[..kb * jb];
            for (g, group) in c.chunks_mut(MR * n).enumerate() {
                let i0 = g * MR;
                if group.len() == MR * n {
                    let (r0, rest) = group.split_at_mut(n);
                    let (r1, rest) = rest.split_at_mut(n);
                    let (r2, r3) = rest.split_at_mut(n);
                    nn_micro(
                        [
                            &a[i0 * k + kc..i0 * k + kc + kb],
                            &a[(i0 + 1) * k + kc..(i0 + 1) * k + kc + kb],
                            &a[(i0 + 2) * k + kc..(i0 + 2) * k + kc + kb],
                            &a[(i0 + 3) * k + kc..(i0 + 3) * k + kc + kb],
                        ],
                        packed,
                        [
                            &mut r0[jc..jc + jb],
                            &mut r1[jc..jc + jb],
                            &mut r2[jc..jc + jb],
                            &mut r3[jc..jc + jb],
                        ],
                        jb,
                    );
                } else {
                    // Tail rows (< MR): single-row axpy over the block.
                    for (ri, row) in group.chunks_mut(n).enumerate() {
                        let i = i0 + ri;
                        let a_seg = &a[i * k + kc..i * k + kc + kb];
                        let c_seg = &mut row[jc..jc + jb];
                        for (kk, &a_ik) in a_seg.iter().enumerate() {
                            let bq = &packed[kk * jb..(kk + 1) * jb];
                            for (c_v, &b_v) in c_seg.iter_mut().zip(bq) {
                                *c_v += a_ik * b_v;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Size dispatch for the quantized nn family over one row panel — same
/// thresholds as the f32 kernels, and (by the accumulation-order policy)
/// the same numerics either way.
fn qnn_panel<T: Scalar>(a: &[T], k: usize, w: &QuantMatrix<T>, c: &mut [T]) {
    let n = w.cols;
    let rows = c.len() / n;
    if rows * k * n < TILE_MIN_FLOPS || rows < TILE_MIN_ROWS {
        qnn_simple(a, k, w, c);
    } else {
        T::with_scratch(KC * NC, |pack| qnn_tiled(a, k, w, c, pack));
    }
}

/// Plain kernel: C += A @ Ŵᵀ over a row panel. Dot-then-add like
/// `matmul`'s `nt_simple` (each element accumulated in a register over
/// the full ascending k range, added to C once).
fn qnt_simple<T: Scalar>(a: &[T], k: usize, w: &QuantMatrix<T>, c: &mut [T]) {
    let n = w.rows;
    let bpr = w.blocks_per_row();
    if k == 0 {
        // Dot-then-add semantics: an empty dot still adds +0.0.
        for c_v in c.iter_mut() {
            *c_v += T::ZERO;
        }
        return;
    }
    for (a_row, c_row) in a.chunks_exact(k).zip(c.chunks_exact_mut(n)) {
        for (j, c_v) in c_row.iter_mut().enumerate() {
            let qrow = &w.q[j * k..(j + 1) * k];
            let srow = &w.scales[j * bpr..(j + 1) * bpr];
            let mut acc = T::ZERO;
            for (blk, (qchunk, achunk)) in
                qrow.chunks(QUANT_BLOCK).zip(a_row.chunks(QUANT_BLOCK)).enumerate()
            {
                let s = srow[blk];
                for (&x, &qv) in achunk.iter().zip(qchunk) {
                    acc += x * dq(s, qv);
                }
            }
            *c_v += acc;
        }
    }
}

/// Tiled kernel for A @ Ŵᵀ: identical structure to `matmul`'s
/// `nt_tiled`, with dequant fused into the Ŵᵀ pack step.
fn qnt_tiled<T: Scalar>(a: &[T], k: usize, w: &QuantMatrix<T>, c: &mut [T], scratch: &mut [T]) {
    let n = w.rows;
    let bpr = w.blocks_per_row();
    let (bt, wt) = scratch.split_at_mut(k * NC);
    for jc in (0..n).step_by(NC) {
        let jb = NC.min(n - jc);
        for jj in 0..jb {
            let row = jc + jj;
            let qrow = &w.q[row * k..(row + 1) * k];
            let srow = &w.scales[row * bpr..(row + 1) * bpr];
            for (kk, &qv) in qrow.iter().enumerate() {
                bt[kk * jb + jj] = dq(srow[kk / QUANT_BLOCK], qv);
            }
        }
        for (g, group) in c.chunks_mut(MR * n).enumerate() {
            let i0 = g * MR;
            let gr = (group.len() / n).min(MR);
            let w_tile = &mut wt[..gr * jb];
            w_tile.fill(T::ZERO);
            for kk in 0..k {
                let bq = &bt[kk * jb..(kk + 1) * jb];
                for r in 0..gr {
                    let x = a[(i0 + r) * k + kk];
                    let w_row = &mut w_tile[r * jb..(r + 1) * jb];
                    for (w_v, &b_v) in w_row.iter_mut().zip(bq) {
                        *w_v += x * b_v;
                    }
                }
            }
            for (r, row) in group.chunks_mut(n).enumerate() {
                let c_seg = &mut row[jc..jc + jb];
                let w_row = &w_tile[r * jb..(r + 1) * jb];
                for (c_v, &w_v) in c_seg.iter_mut().zip(w_row) {
                    *c_v += w_v;
                }
            }
        }
    }
}

/// Size dispatch for the quantized nt family over one row panel.
fn qnt_panel<T: Scalar>(a: &[T], k: usize, w: &QuantMatrix<T>, c: &mut [T]) {
    let n = w.rows;
    let rows = c.len() / n;
    if k == 0 || rows * k * n < TILE_MIN_FLOPS || rows < TILE_MIN_ROWS {
        qnt_simple(a, k, w, c);
    } else {
        T::with_scratch(k * NC + MR * NC, |scratch| qnt_tiled(a, k, w, c, scratch));
    }
}

// ---------------------------------------------------------------------------
// Public API — mirrors the matmul flavours the model layer uses
// ---------------------------------------------------------------------------

/// C = A @ Ŵ, allocating.
pub fn quant_matmul<T: Scalar>(a: &Matrix<T>, w: &QuantMatrix<T>) -> Matrix<T> {
    assert_eq!(a.cols, w.rows, "quant_matmul shape mismatch: {:?} @ {:?}", a.shape(), w.shape());
    let mut c = Matrix::zeros(a.rows, w.cols);
    quant_matmul_acc_slice(a, w, &mut c.data);
    c
}

/// C = A @ Ŵ, overwriting an existing buffer.
pub fn quant_matmul_into<T: Scalar>(a: &Matrix<T>, w: &QuantMatrix<T>, c: &mut Matrix<T>) {
    assert_eq!((c.rows, c.cols), (a.rows, w.cols));
    c.fill(T::ZERO);
    quant_matmul_acc_slice(a, w, &mut c.data);
}

/// C += A @ Ŵ with C a row-major `a.rows × w.cols` slice. Threaded over
/// row panels exactly like `matmul_acc_slice`.
pub fn quant_matmul_acc_slice<T: Scalar>(a: &Matrix<T>, w: &QuantMatrix<T>, c: &mut [T]) {
    assert_eq!(a.cols, w.rows, "quant_matmul shape mismatch: {:?} @ {:?}", a.shape(), w.shape());
    let (m, k, n) = (a.rows, a.cols, w.cols);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let threads = threads_for(m * k * n, m);
    let a_data = &a.data;
    let c_ptr = SendPtr(c.as_mut_ptr());
    run_row_panels(m, threads, &|lo, hi| {
        let c_ptr = &c_ptr;
        // SAFETY: row panels [lo, hi) are disjoint across pool lanes.
        let c_panel = unsafe { std::slice::from_raw_parts_mut(c_ptr.0.add(lo * n), (hi - lo) * n) };
        qnn_panel(&a_data[lo * k..hi * k], k, w, c_panel);
    });
}

/// C = A @ Ŵᵀ, overwriting an existing buffer (the `dx = dy @ Wᵀ`
/// backward shape; serving uses it for adapter-free backbone modules).
pub fn quant_matmul_nt_into<T: Scalar>(a: &Matrix<T>, w: &QuantMatrix<T>, c: &mut Matrix<T>) {
    assert_eq!((c.rows, c.cols), (a.rows, w.rows));
    c.fill(T::ZERO);
    quant_matmul_nt_acc_slice(a, w, &mut c.data);
}

/// C += A @ Ŵᵀ with C a row-major `a.rows × w.rows` slice.
pub fn quant_matmul_nt_acc_slice<T: Scalar>(a: &Matrix<T>, w: &QuantMatrix<T>, c: &mut [T]) {
    assert_eq!(
        a.cols,
        w.cols,
        "quant_matmul_nt shape mismatch: {:?} @ {:?}ᵀ",
        a.shape(),
        w.shape()
    );
    let (m, k, n) = (a.rows, a.cols, w.rows);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let threads = threads_for(m * k * n, m);
    let a_data = &a.data;
    let c_ptr = SendPtr(c.as_mut_ptr());
    run_row_panels(m, threads, &|lo, hi| {
        let c_ptr = &c_ptr;
        // SAFETY: row panels [lo, hi) are disjoint across pool lanes.
        let c_panel = unsafe { std::slice::from_raw_parts_mut(c_ptr.0.add(lo * n), (hi - lo) * n) };
        qnt_panel(&a_data[lo * k..hi * k], k, w, c_panel);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul, matmul_nt};
    use crate::linalg::matrix::{DMat, Mat};
    use crate::util::rng::Rng;

    #[test]
    fn round_trip_error_within_budget() {
        let mut rng = Rng::new(11);
        for &(r, c) in &[(3usize, 64usize), (5, 100), (8, 129), (1, 1), (4, 63)] {
            let m = Mat::randn(r, c, 1.5, &mut rng);
            let qm = QuantMat::quantize(&m);
            let back = qm.dequantize();
            for i in 0..r {
                for (blk, chunk) in m.row(i).chunks(QUANT_BLOCK).enumerate() {
                    let absmax = chunk.iter().fold(0f32, |a, &v| a.max(v.abs()));
                    let budget = absmax / 254.0 + 1e-12;
                    for (j0, (&x, &xh)) in
                        chunk.iter().zip(back.row(i)[blk * QUANT_BLOCK..].iter()).enumerate()
                    {
                        assert!(
                            (x - xh).abs() <= budget,
                            "({i},{blk},{j0}): |{x} - {xh}| > {budget}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn zero_block_round_trips_exactly() {
        let m = Mat::zeros(3, 130);
        let qm = QuantMat::quantize(&m);
        assert_eq!(qm.dequantize().data, m.data);
        assert!(qm.scales.iter().all(|&s| s == 0.0));
    }

    #[test]
    fn quant_matmul_bit_matches_dequant_then_matmul() {
        let mut rng = Rng::new(13);
        // Shapes straddling the tile thresholds: simple, tiled and
        // threaded paths must all equal matmul on the dequantized dense
        // matrix bit-for-bit (same dq values, same accumulation order).
        let shapes = [(1usize, 24usize, 24usize), (9, 64, 100), (40, 150, 130), (96, 128, 256)];
        for &(m, k, n) in &shapes {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let w = Mat::randn(k, n, 0.7, &mut rng);
            let qw = QuantMat::quantize(&w);
            let dense = qw.dequantize();
            let c = quant_matmul(&a, &qw);
            let c_ref = matmul(&a, &dense);
            assert_eq!(c.data, c_ref.data, "nn mismatch at {m}x{k}x{n}");

            let b = Mat::randn(n, k, 0.9, &mut rng);
            let qb = QuantMat::quantize(&b);
            let mut d = Mat::filled(m, n, 3.25); // dirty buffer
            quant_matmul_nt_into(&a, &qb, &mut d);
            let d_ref = matmul_nt(&a, &qb.dequantize());
            assert_eq!(d.data, d_ref.data, "nt mismatch at {m}x{k}x{n}");
        }
    }

    #[test]
    fn single_row_bit_matches_batched_row() {
        let mut rng = Rng::new(17);
        let x = Mat::randn(12, 80, 1.0, &mut rng);
        let w = QuantMat::quantize(&Mat::randn(80, 96, 1.0, &mut rng));
        let full = quant_matmul(&x, &w);
        for t in [0usize, 5, 11] {
            let row = Mat::from_vec(1, 80, x.row(t).to_vec());
            let y = quant_matmul(&row, &w);
            assert_eq!(y.data, full.row(t), "row {t} diverged from batched product");
        }
    }

    #[test]
    fn row_gather_matches_dequantized_rows() {
        let mut rng = Rng::new(19);
        let m = Mat::randn(6, 100, 1.0, &mut rng);
        let qm = QuantMat::quantize(&m);
        let dense = qm.dequantize();
        let mut out = vec![0.0f32; 100];
        qm.dequant_row_into(3, &mut out);
        assert_eq!(out, dense.row(3));
        let mut acc = dense.row(1).to_vec();
        qm.add_row_into(4, &mut acc);
        let want: Vec<f32> =
            dense.row(1).iter().zip(dense.row(4)).map(|(&a, &b)| a + b).collect();
        assert_eq!(acc, want);
    }

    #[test]
    fn f64_round_trip_within_budget() {
        let mut rng = Rng::new(23);
        let m = DMat::randn(4, 100, 2.0, &mut rng);
        let qm = QuantDMat::quantize(&m);
        let back = qm.dequantize();
        for i in 0..m.rows {
            for (blk, chunk) in m.row(i).chunks(QUANT_BLOCK).enumerate() {
                let absmax = chunk.iter().fold(0f64, |a, &v| a.max(v.abs()));
                let budget = absmax / 254.0 + 1e-15;
                for (&x, &xh) in chunk.iter().zip(back.row(i)[blk * QUANT_BLOCK..].iter()) {
                    assert!((x - xh).abs() <= budget);
                }
            }
        }
    }

    #[test]
    fn bytes_ratio_beats_gate() {
        let m = Mat::randn(64, 256, 1.0, &mut Rng::new(29));
        let qm = QuantMat::quantize(&m);
        let f32_bytes = m.data.len() * 4;
        assert!((qm.bytes() as f64) / (f32_bytes as f64) < 0.35);
    }
}
