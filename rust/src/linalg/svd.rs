//! Singular value decomposition — one-sided Jacobi (f64).
//!
//! The exact SVD is the substrate behind PSOFT/PiSSA/LoRA-XS/SVFT
//! initialization (Eq. 6: A' = U[:, :r], B' = Σ[:r,:r] V[:, :r]ᵀ,
//! W_res = W_pre − A'B') and behind the spectra of the synthetic pre-trained
//! weights. One-sided Jacobi is simple, accurate to machine precision, and
//! fast enough at the layer widths we train (≤ 1024).

use super::matrix::DMat;

/// Thin SVD result: `a = u · diag(s) · vt` with `u: m×k`, `s: k`, `vt: k×n`,
/// `k = min(m, n)`, singular values descending.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: DMat,
    pub s: Vec<f64>,
    pub vt: DMat,
}

impl Svd {
    /// Reconstruct `u[:, :r] · diag(s[:r]) · vt[:r, :]`.
    pub fn reconstruct(&self, r: usize) -> DMat {
        let r = r.min(self.s.len());
        let (m, n) = (self.u.rows, self.vt.cols);
        let mut out = DMat::zeros(m, n);
        for k in 0..r {
            let sk = self.s[k];
            for i in 0..m {
                let uik = self.u[(i, k)] * sk;
                if uik == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out[(i, j)] += uik * self.vt[(k, j)];
                }
            }
        }
        out
    }

    /// Full reconstruction (all singular values).
    pub fn reconstruct_full(&self) -> DMat {
        self.reconstruct(self.s.len())
    }
}

/// Compute the thin SVD by one-sided Jacobi.
pub fn svd(a: &DMat) -> Svd {
    if a.rows >= a.cols {
        svd_tall(a)
    } else {
        // A = U Σ Vᵀ  ⇔  Aᵀ = V Σ Uᵀ.
        let s = svd_tall(&a.transpose());
        Svd { u: s.vt.transpose(), s: s.s, vt: s.u.transpose() }
    }
}

/// One-sided Jacobi on a tall (m ≥ n) matrix: right-rotations orthogonalize
/// column pairs of a working copy G (= U·Σ at convergence) while the same
/// rotations accumulate into V.
fn svd_tall(a: &DMat) -> Svd {
    let (m, n) = a.shape();
    assert!(m >= n);
    let mut g = a.clone();
    let mut v = DMat::eye(n);

    let tol = 1e-14;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries for the (p, q) column pair.
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for i in 0..m {
                    let gp = g[(i, p)];
                    let gq = g[(i, q)];
                    app += gp * gp;
                    aqq += gq * gq;
                    apq += gp * gq;
                }
                let denom = (app * aqq).sqrt();
                if denom <= 0.0 || apq.abs() <= tol * denom {
                    continue;
                }
                off = off.max(apq.abs() / denom);

                // Jacobi rotation zeroing the off-diagonal Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let gp = g[(i, p)];
                    let gq = g[(i, q)];
                    g[(i, p)] = c * gp - s * gq;
                    g[(i, q)] = s * gp + c * gq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if off < tol {
            break;
        }
    }

    // Column norms of G are the singular values; normalize to get U.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = (0..n).map(|j| g.col_norm(j)).collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());

    let mut u = DMat::zeros(m, n);
    let mut s = Vec::with_capacity(n);
    let mut vt = DMat::zeros(n, n);
    for (new_j, &old_j) in order.iter().enumerate() {
        let sigma = norms[old_j];
        s.push(sigma);
        if sigma > 1e-300 {
            for i in 0..m {
                u[(i, new_j)] = g[(i, old_j)] / sigma;
            }
        } else {
            // Null direction: leave U column zero (caller never uses it with
            // sigma=0 weight); keep V orthonormal regardless.
            u[(new_j.min(m - 1), new_j)] = 1.0;
        }
        for i in 0..n {
            vt[(new_j, i)] = v[(i, old_j)];
        }
    }
    Svd { u, s, vt }
}

/// Spectral norm (largest singular value) via a few power iterations —
/// cheaper than a full SVD when only σ₁ is needed.
pub fn spectral_norm(a: &DMat, iters: usize, seed: u64) -> f64 {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let n = a.cols;
    let mut x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let norm = |v: &[f64]| v.iter().map(|x| x * x).sum::<f64>().sqrt();
    let mut sigma = 0.0;
    for _ in 0..iters.max(1) {
        // y = Aᵀ (A x)
        let mut ax = vec![0.0; a.rows];
        for i in 0..a.rows {
            ax[i] = a.row(i).iter().zip(&x).map(|(&aij, &xj)| aij * xj).sum();
        }
        let mut y = vec![0.0; n];
        for i in 0..a.rows {
            let axi = ax[i];
            for (j, yj) in y.iter_mut().enumerate() {
                *yj += a[(i, j)] * axi;
            }
        }
        let ny = norm(&y);
        if ny < 1e-300 {
            return 0.0;
        }
        sigma = ny.sqrt();
        for (xj, yj) in x.iter_mut().zip(&y) {
            *xj = yj / ny;
        }
    }
    sigma
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;
    use crate::linalg::qr::orthonormality_error;
    use crate::util::rng::Rng;

    #[test]
    fn reconstructs_random_matrices() {
        let mut rng = Rng::new(5);
        for &(m, n) in &[(4, 4), (12, 7), (7, 12), (32, 16), (16, 33)] {
            let a = DMat::randn(m, n, 1.0, &mut rng);
            let d = svd(&a);
            assert!(d.reconstruct_full().dist(&a) < 1e-9, "{m}x{n}");
            // Descending singular values.
            for w in d.s.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
        }
    }

    #[test]
    fn factors_are_orthonormal() {
        let mut rng = Rng::new(6);
        let a = DMat::randn(20, 9, 1.0, &mut rng);
        let d = svd(&a);
        assert!(orthonormality_error(&d.u) < 1e-10);
        assert!(orthonormality_error(&d.vt.transpose()) < 1e-10);
    }

    #[test]
    fn known_diagonal() {
        let a = DMat::diag(&[3.0, 1.0, 2.0]);
        let d = svd(&a);
        assert!((d.s[0] - 3.0).abs() < 1e-12);
        assert!((d.s[1] - 2.0).abs() < 1e-12);
        assert!((d.s[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn low_rank_truncation_is_best_approx() {
        // Rank-2 matrix: truncating at r=2 reconstructs exactly.
        let mut rng = Rng::new(7);
        let u = DMat::randn(10, 2, 1.0, &mut rng);
        let v = DMat::randn(2, 8, 1.0, &mut rng);
        let a = matmul(&u, &v);
        let d = svd(&a);
        assert!(d.reconstruct(2).dist(&a) < 1e-9);
        assert!(d.s[2].abs() < 1e-9);
    }

    #[test]
    fn rank_deficient_exact_zero_sigma() {
        let mut a = DMat::zeros(5, 3);
        for i in 0..5 {
            a[(i, 0)] = (i + 1) as f64;
            a[(i, 1)] = 2.0 * (i + 1) as f64; // col1 = 2*col0
            a[(i, 2)] = (i as f64).sin();
        }
        let d = svd(&a);
        assert!(d.s[2] < 1e-10);
        assert!(d.reconstruct_full().dist(&a) < 1e-9);
    }

    #[test]
    fn spectral_norm_close_to_sigma1() {
        let mut rng = Rng::new(8);
        let a = DMat::randn(15, 10, 1.0, &mut rng);
        let d = svd(&a);
        let sn = spectral_norm(&a, 50, 123);
        assert!((sn - d.s[0]).abs() / d.s[0] < 1e-6, "{sn} vs {}", d.s[0]);
    }
}
