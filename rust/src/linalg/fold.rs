//! Merge-time fold kernels.
//!
//! Helpers for collapsing structured adapter factorizations into one dense
//! weight (`Adapter::merge_into`). These run once per promotion/export —
//! never on the per-token path — so they favour clarity over blocking;
//! what matters is that a fold is deterministic (repeated folds of the same
//! adapter state are bit-identical, which merged-artifact round-trips and
//! re-promotion after a spill rely on).

use super::matrix::{Matrix, Scalar};

/// dst += (A · diag(s)) · B without materializing the scaled A — the
/// diagonal-sandwich fold shared by VeRA (`A_f·diag(d)·B_f`) and SVFT
/// (`U·diag(σ+m)·Vᵀ`). Accumulates each element in ascending shared-index
/// order (single pass, no tiling), so the fold is deterministic.
pub fn diag_matmul_acc<T: Scalar>(a: &Matrix<T>, s: &[T], b: &Matrix<T>, dst: &mut Matrix<T>) {
    let (m, k) = (a.rows, a.cols);
    let n = b.cols;
    assert_eq!(b.rows, k, "inner dims");
    assert_eq!(s.len(), k, "diagonal length");
    assert_eq!((dst.rows, dst.cols), (m, n), "output shape");
    for i in 0..m {
        let a_row = a.row(i);
        let d_row = dst.row_mut(i);
        for kk in 0..k {
            let av = a_row[kk] * s[kk];
            let b_row = b.row(kk);
            for (d_v, &b_v) in d_row.iter_mut().zip(b_row) {
                *d_v += av * b_v;
            }
        }
    }
}

/// dst = blockdiag(rots) · W₀ — the OFT merge fold. Block `k` (size b)
/// overwrites rows `[off, off+b)` of `dst` with `R_k · W₀[off..off+b, :]`;
/// the blocks must tile `W₀.rows`. The weight-side twin of
/// [`super::block_rot_matmul_into`] (which rotates activations instead):
/// after this fold, a plain dense matmul against `dst` replaces the
/// per-token rotate-then-multiply pair.
pub fn block_rot_fold_into<T: Scalar>(rots: &[Matrix<T>], w0: &Matrix<T>, dst: &mut Matrix<T>) {
    let (d, n) = (w0.rows, w0.cols);
    assert_eq!((dst.rows, dst.cols), (d, n), "output shape");
    assert_eq!(rots.iter().map(|r| r.rows).sum::<usize>(), d, "blocks must tile d");
    let mut off = 0;
    for rot in rots {
        let b = rot.rows;
        assert_eq!(rot.cols, b, "rotation blocks are square");
        for i in 0..b {
            let r_row = rot.row(i);
            let d_row = dst.row_mut(off + i);
            d_row.iter_mut().for_each(|v| *v = T::ZERO);
            for (kk, &r_v) in r_row.iter().enumerate() {
                let w_row = w0.row(off + kk);
                for (d_v, &w_v) in d_row.iter_mut().zip(w_row) {
                    *d_v += r_v * w_v;
                }
            }
        }
        off += b;
    }
}

#[cfg(test)]
mod tests {
    use super::super::{matmul, Mat};
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn diag_matmul_matches_scaled_matmul() {
        let mut rng = Rng::new(71);
        let a = Mat::randn(6, 4, 0.5, &mut rng);
        let b = Mat::randn(4, 5, 0.5, &mut rng);
        let s: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
        let mut out = Mat::zeros(6, 5);
        diag_matmul_acc(&a, &s, &b, &mut out);
        let reference = matmul(&a.scale_cols(&s), &b);
        assert!(out.dist(&reference) < 1e-6, "dist {}", out.dist(&reference));
    }

    #[test]
    fn block_rot_fold_matches_per_block_matmul() {
        let mut rng = Rng::new(72);
        let w = Mat::randn(10, 7, 0.5, &mut rng);
        let rots =
            vec![Mat::randn(4, 4, 0.5, &mut rng), Mat::randn(4, 4, 0.5, &mut rng), Mat::randn(2, 2, 0.5, &mut rng)];
        let mut out = Mat::zeros(10, 7);
        block_rot_fold_into(&rots, &w, &mut out);
        let mut off = 0;
        for rot in &rots {
            let b = rot.rows;
            let blk = matmul(rot, &w.rows_range(off, off + b));
            assert!(out.rows_range(off, off + b).dist(&blk) < 1e-6);
            off += b;
        }
    }

    #[test]
    fn folds_are_deterministic() {
        let mut rng = Rng::new(73);
        let a = Mat::randn(8, 3, 0.5, &mut rng);
        let b = Mat::randn(3, 6, 0.5, &mut rng);
        let s: Vec<f32> = (0..3).map(|_| rng.normal() as f32).collect();
        let mut out1 = Mat::zeros(8, 6);
        let mut out2 = Mat::zeros(8, 6);
        diag_matmul_acc(&a, &s, &b, &mut out1);
        diag_matmul_acc(&a, &s, &b, &mut out2);
        assert_eq!(out1.data, out2.data);
    }
}
