//! Householder QR decomposition (f64).
//!
//! Used by the randomized SVD's range finder (Halko et al. 2011) and by the
//! orthogonal-initialization ablation (paper Table 7: `A_orth R B`).

use super::matrix::DMat;

/// Thin QR: A (m×n, m ≥ n) = Q (m×n, orthonormal columns) · R (n×n, upper
/// triangular with non-negative diagonal).
pub fn qr_thin(a: &DMat) -> (DMat, DMat) {
    let (m, n) = a.shape();
    assert!(m >= n, "qr_thin expects m >= n, got {m}x{n}");
    let mut r = a.clone();
    // Householder vectors stored per-column.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);

    for k in 0..n {
        // Build the Householder reflector for column k below the diagonal.
        let mut v: Vec<f64> = (k..m).map(|i| r[(i, k)]).collect();
        let alpha = {
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if v[0] >= 0.0 {
                -norm
            } else {
                norm
            }
        };
        if alpha.abs() < 1e-300 {
            // Zero column: identity reflector.
            vs.push(vec![0.0; m - k]);
            continue;
        }
        v[0] -= alpha;
        let vnorm2 = v.iter().map(|x| x * x).sum::<f64>();
        if vnorm2 < 1e-300 {
            vs.push(vec![0.0; m - k]);
            continue;
        }
        // Apply reflector to R's trailing block: R -= 2 v (vᵀ R) / vᵀv.
        for j in k..n {
            let dot: f64 = (k..m).map(|i| v[i - k] * r[(i, j)]).sum();
            let scale = 2.0 * dot / vnorm2;
            for i in k..m {
                r[(i, j)] -= scale * v[i - k];
            }
        }
        vs.push(v);
    }

    // Accumulate Q by applying reflectors (in reverse) to the thin identity.
    let mut q = DMat::from_fn(m, n, |i, j| if i == j { 1.0 } else { 0.0 });
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2 = v.iter().map(|x| x * x).sum::<f64>();
        if vnorm2 < 1e-300 {
            continue;
        }
        for j in 0..n {
            let dot: f64 = (k..m).map(|i| v[i - k] * q[(i, j)]).sum();
            let scale = 2.0 * dot / vnorm2;
            for i in k..m {
                q[(i, j)] -= scale * v[i - k];
            }
        }
    }

    // Zero out numerical noise below R's diagonal and make diag(R) >= 0.
    let mut r_thin = DMat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r_thin[(i, j)] = r[(i, j)];
        }
    }
    for i in 0..n {
        if r_thin[(i, i)] < 0.0 {
            for j in i..n {
                r_thin[(i, j)] = -r_thin[(i, j)];
            }
            for row in 0..m {
                q[(row, i)] = -q[(row, i)];
            }
        }
    }
    (q, r_thin)
}

/// Orthonormalize the columns of A (the randomized-SVD range finder step).
pub fn orthonormal_columns(a: &DMat) -> DMat {
    qr_thin(a).0
}

/// ‖QᵀQ − I‖_max — orthonormality defect, used in tests and geometry checks.
pub fn orthonormality_error(q: &DMat) -> f64 {
    let n = q.cols;
    let mut err: f64 = 0.0;
    for i in 0..n {
        for j in 0..n {
            let dot: f64 = (0..q.rows).map(|r| q[(r, i)] * q[(r, j)]).sum();
            let target = if i == j { 1.0 } else { 0.0 };
            err = err.max((dot - target).abs());
        }
    }
    err
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;
    use crate::util::rng::Rng;

    #[test]
    fn reconstructs_a() {
        let mut rng = Rng::new(7);
        for &(m, n) in &[(4, 4), (10, 6), (25, 25), (40, 8)] {
            let a = DMat::randn(m, n, 1.0, &mut rng);
            let (q, r) = qr_thin(&a);
            assert_eq!(q.shape(), (m, n));
            assert_eq!(r.shape(), (n, n));
            let qr = matmul(&q, &r);
            assert!(qr.dist(&a) < 1e-10, "{m}x{n}: dist={}", qr.dist(&a));
        }
    }

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Rng::new(8);
        let a = DMat::randn(30, 12, 1.0, &mut rng);
        let (q, _) = qr_thin(&a);
        assert!(orthonormality_error(&q) < 1e-12);
    }

    #[test]
    fn r_is_upper_triangular_nonneg_diag() {
        let mut rng = Rng::new(9);
        let a = DMat::randn(12, 12, 1.0, &mut rng);
        let (_, r) = qr_thin(&a);
        for i in 0..12 {
            assert!(r[(i, i)] >= 0.0);
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn handles_rank_deficiency() {
        // Column 2 = column 0 + column 1.
        let mut a = DMat::zeros(6, 3);
        let mut rng = Rng::new(10);
        for i in 0..6 {
            a[(i, 0)] = rng.normal();
            a[(i, 1)] = rng.normal();
            a[(i, 2)] = a[(i, 0)] + a[(i, 1)];
        }
        let (q, r) = qr_thin(&a);
        assert!(matmul(&q, &r).dist(&a) < 1e-10);
    }
}
