//! Execution backends.
//!
//! The trainer drives a [`Backend`]: either the **PJRT backend** (the
//! production path — loads `artifacts/*.hlo.txt`, fused fwd+bwd+AdamW runs
//! inside XLA, Rust owns all state buffers) or the **native backend**
//! (pure-Rust mirror used by tests, ablations needing loss hooks, and
//! pretraining).
//!
//! Every step receives the run-level [`Workspace`] owned by the caller
//! (the trainer creates exactly one per fine-tuning run), so scratch
//! buffers warm up once and are shared across train/eval phases. The
//! native backend additionally owns a [`StepBuffers`] and a persistent
//! flat parameter vector, making its steady-state `train_step`
//! allocation-free (see `tests/zero_alloc.rs`).
//!
//! The **multi-adapter serving core** lives in [`serve`]: one shared
//! frozen backbone fronted by N concurrently-registered adapters, each an
//! independent [`NativeBackend`] built via
//! [`NativeBackend::for_adapter`].

pub mod pjrt;
pub mod serve;

use crate::config::PeftConfig;
use crate::linalg::Workspace;
use crate::model::native::{self, Batch, StepBuffers, StepOutput};
use crate::model::{Backbone, NativeModel};
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::Arc;

/// Per-step hyperparameters (mirrors the HLO artifact's `hyper[4]` input).
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    pub lr: f64,
    pub head_lr: f64,
    pub weight_decay: f64,
    pub gamma_orth: f64,
    pub grad_clip: f64,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper { lr: 4e-4, head_lr: 5e-4, weight_decay: 0.0, gamma_orth: 0.0, grad_clip: 1.0 }
    }
}

pub trait Backend {
    /// One optimizer step on a batch; returns loss/metric of the batch.
    /// `ws` is the run-owned scratch workspace.
    fn train_step(&mut self, batch: &Batch, hyper: &Hyper, ws: &mut Workspace)
        -> Result<StepOutput>;

    /// Forward-only evaluation.
    fn evaluate(&mut self, batch: &Batch, ws: &mut Workspace) -> Result<StepOutput>;

    fn trainable(&self) -> Vec<f32>;
    fn set_trainable(&mut self, p: &[f32]) -> Result<()>;
    fn num_trainable(&self) -> usize;
    fn name(&self) -> &'static str;

    /// Optimizer steps taken so far.
    fn steps(&self) -> usize;
}

/// AdamW state shared by both backends' Rust-side implementations.
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: usize,
}

impl AdamState {
    pub fn new(n: usize) -> Self {
        AdamState { m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }
}

/// Native backend: NativeModel + Rust AdamW, with all per-step state
/// (activations, gradients, parameter vector, optimizer moments)
/// preallocated and updated in place.
pub struct NativeBackend {
    pub model: NativeModel,
    pub opt: AdamState,
    /// Reusable activation/gradient buffers (keyed by batch shape).
    pub bufs: StepBuffers,
    /// Persistent flat parameter vector, kept in sync with the model.
    params: Vec<f32>,
    beta1: f64,
    beta2: f64,
    eps: f64,
}

impl NativeBackend {
    pub fn new(model: NativeModel) -> Self {
        let n = model.num_trainable();
        let params = model.trainable_flat();
        NativeBackend {
            model,
            opt: AdamState::new(n),
            bufs: StepBuffers::new(),
            params,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }

    /// Build a backend for one adapter on a shared frozen backbone (the
    /// serve path): the frozen tensors stay `Arc`-shared with `backbone`
    /// and every sibling adapter; only adapter/head/optimizer state is
    /// owned. Identical construction to `NativeBackend::new` over
    /// `NativeModel::from_backbone` with a seed-`seed` Rng — so serve-side
    /// results are bit-comparable to a standalone run.
    pub fn for_adapter(backbone: &Arc<Backbone>, peft: &PeftConfig, seed: u64) -> NativeBackend {
        let mut rng = Rng::new(seed);
        NativeBackend::new(NativeModel::from_backbone(backbone, peft, &mut rng))
    }

    /// The full optimizer step without constructing a `StepOutput`:
    /// forward + backward into `self.bufs`, global-norm clip, in-place
    /// AdamW on the persistent parameter vector, write-back into the
    /// model. Returns (loss, metric); per-example predictions are left in
    /// `self.bufs.preds`. This is the allocation-free hot path the
    /// counting-allocator test exercises.
    pub fn step_core(&mut self, batch: &Batch, hyper: &Hyper, ws: &mut Workspace) -> (f64, f64) {
        let (loss, metric) =
            native::train_grads_into(&self.model, batch, hyper.gamma_orth, &mut self.bufs, ws);
        let grads = &mut self.bufs.grads;

        // Global-norm clip (matches the artifact).
        let gnorm = grads.iter().map(|g| (*g as f64) * (*g as f64)).sum::<f64>().sqrt().max(1e-12);
        if gnorm > hyper.grad_clip {
            let s = (hyper.grad_clip / gnorm) as f32;
            for g in grads.iter_mut() {
                *g *= s;
            }
        }

        self.opt.step += 1;
        let t = self.opt.step as f64;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        let head_off = self.model.head_offset();
        for i in 0..self.params.len() {
            let g = grads[i] as f64;
            let m = self.beta1 * self.opt.m[i] as f64 + (1.0 - self.beta1) * g;
            let v = self.beta2 * self.opt.v[i] as f64 + (1.0 - self.beta2) * g * g;
            self.opt.m[i] = m as f32;
            self.opt.v[i] = v as f32;
            let update = (m / bc1) / ((v / bc2).sqrt() + self.eps);
            let lr = if i >= head_off { hyper.head_lr } else { hyper.lr };
            let p = self.params[i] as f64;
            self.params[i] = (p * (1.0 - lr * hyper.weight_decay) - lr * update) as f32;
        }
        self.model.set_trainable_flat(&self.params);
        (loss, metric)
    }
}

impl Backend for NativeBackend {
    fn train_step(
        &mut self,
        batch: &Batch,
        hyper: &Hyper,
        ws: &mut Workspace,
    ) -> Result<StepOutput> {
        let (loss, metric) = self.step_core(batch, hyper, ws);
        Ok(StepOutput { loss, metric, preds: self.bufs.preds.clone() })
    }

    fn evaluate(&mut self, batch: &Batch, ws: &mut Workspace) -> Result<StepOutput> {
        let (loss, metric) = native::evaluate_into(&self.model, batch, &mut self.bufs, ws);
        Ok(StepOutput { loss, metric, preds: self.bufs.preds.clone() })
    }

    fn trainable(&self) -> Vec<f32> {
        self.params.clone()
    }

    fn set_trainable(&mut self, p: &[f32]) -> Result<()> {
        self.model.set_trainable_flat(p);
        self.params.copy_from_slice(p);
        Ok(())
    }

    fn num_trainable(&self) -> usize {
        self.model.num_trainable()
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn steps(&self) -> usize {
        self.opt.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MethodKind, ModelConfig, ModuleKind, PeftConfig};
    use crate::model::native::Target;
    use crate::model::Backbone;
    use crate::util::rng::Rng;

    fn tiny() -> (NativeBackend, Batch) {
        let mut rng = Rng::new(401);
        let cfg = ModelConfig {
            arch: crate::config::Arch::Encoder,
            vocab_size: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            max_seq: 10,
            n_classes: 2,
        };
        let bb = Backbone::random(&cfg, &mut rng);
        let peft = PeftConfig::new(MethodKind::Psoft, 4)
            .with_modules(vec![ModuleKind::Q, ModuleKind::V]);
        let model = NativeModel::from_backbone(&bb, &peft, &mut rng);
        let tokens: Vec<i32> = (0..8 * 8).map(|_| rng.below(32) as i32).collect();
        let labels: Vec<usize> = (0..8).map(|b| (tokens[b * 8] as usize) % 2).collect();
        let batch = Batch {
            batch: 8,
            seq: 8,
            tokens,
            pad: vec![1.0; 64],
            target: Target::Class(labels),
        };
        (NativeBackend::new(model), batch)
    }

    #[test]
    fn adamw_reduces_loss() {
        let (mut be, batch) = tiny();
        let mut ws = Workspace::new();
        let hyper = Hyper { lr: 5e-3, head_lr: 5e-3, ..Default::default() };
        let first = be.train_step(&batch, &hyper, &mut ws).unwrap().loss;
        let mut last = first;
        for _ in 0..40 {
            last = be.train_step(&batch, &hyper, &mut ws).unwrap().loss;
        }
        assert!(last < first * 0.8, "{first} -> {last}");
        assert_eq!(be.steps(), 41);
    }

    #[test]
    fn grad_clip_bounds_update() {
        let (mut be, batch) = tiny();
        let mut ws = Workspace::new();
        let p0 = be.trainable();
        let hyper = Hyper { lr: 1.0, head_lr: 1.0, grad_clip: 1e-8, ..Default::default() };
        be.train_step(&batch, &hyper, &mut ws).unwrap();
        let p1 = be.trainable();
        // With a vanishing clip, first-step Adam update magnitude is tiny
        // relative to lr=1 unclipped behaviour.
        let delta: f64 =
            p0.iter().zip(&p1).map(|(a, b)| ((a - b) as f64).abs()).fold(0.0, f64::max);
        assert!(delta < 0.5, "max delta {delta}");
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let (mut be, batch) = tiny();
        let mut ws = Workspace::new();
        // Isolate decay: zero LR on updates is impossible (decay is scaled
        // by lr), so compare decay vs no-decay trajectories.
        let p0 = be.trainable();
        let hyper = Hyper { lr: 1e-3, head_lr: 1e-3, weight_decay: 0.5, ..Default::default() };
        be.train_step(&batch, &hyper, &mut ws).unwrap();
        let p_decay = be.trainable();
        let (mut be2, _) = tiny();
        be2.set_trainable(&p0).unwrap();
        let hyper2 = Hyper { lr: 1e-3, head_lr: 1e-3, weight_decay: 0.0, ..Default::default() };
        be2.train_step(&batch, &hyper2, &mut ws).unwrap();
        let p_plain = be2.trainable();
        let norm_decay: f64 = p_decay.iter().map(|v| (*v as f64).powi(2)).sum();
        let norm_plain: f64 = p_plain.iter().map(|v| (*v as f64).powi(2)).sum();
        assert!(norm_decay < norm_plain);
    }

    #[test]
    fn trainable_stays_in_sync_with_model() {
        let (mut be, batch) = tiny();
        let mut ws = Workspace::new();
        let hyper = Hyper { lr: 5e-3, head_lr: 5e-3, ..Default::default() };
        for _ in 0..3 {
            be.train_step(&batch, &hyper, &mut ws).unwrap();
        }
        // The persistent flat vector must match a fresh flatten of the
        // model after in-place updates.
        assert_eq!(be.trainable(), be.model.trainable_flat());
    }
}
