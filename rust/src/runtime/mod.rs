//! Execution backends.
//!
//! The trainer drives a [`Backend`]: either the **PJRT backend** (the
//! production path — loads `artifacts/*.hlo.txt`, fused fwd+bwd+AdamW runs
//! inside XLA, Rust owns all state buffers) or the **native backend**
//! (pure-Rust mirror used by tests, ablations needing loss hooks, and
//! pretraining).
//!
//! Every step receives the run-level [`Workspace`] owned by the caller
//! (the trainer creates exactly one per fine-tuning run), so scratch
//! buffers warm up once and are shared across train/eval phases. The
//! native backend additionally owns a [`StepBuffers`] and a persistent
//! flat parameter vector, making its steady-state `train_step`
//! allocation-free (see `tests/zero_alloc.rs`).
//!
//! The **multi-adapter serving core** lives in [`serve`]: one shared
//! frozen backbone fronted by N concurrently-registered adapters, each an
//! independent [`NativeBackend`] built via
//! [`NativeBackend::for_adapter`].

pub mod loadgen;
pub mod pjrt;
pub mod serve;

use crate::config::{Arch, ModuleKind, PeftConfig};
use crate::linalg::Workspace;
use crate::model::native::{self, Batch, StepBuffers, StepOutput};
use crate::model::{Backbone, ModuleOp, NativeModel};
use crate::peft::artifact::{AdapterArtifact, ArtifactError, SCHEMA_VERSION};
use crate::peft::{Section, StateError};
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::Arc;

/// Per-step hyperparameters (mirrors the HLO artifact's `hyper[4]` input).
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    pub lr: f64,
    pub head_lr: f64,
    pub weight_decay: f64,
    pub gamma_orth: f64,
    pub grad_clip: f64,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper { lr: 4e-4, head_lr: 5e-4, weight_decay: 0.0, gamma_orth: 0.0, grad_clip: 1.0 }
    }
}

pub trait Backend {
    /// One optimizer step on a batch; returns loss/metric of the batch.
    /// `ws` is the run-owned scratch workspace.
    fn train_step(&mut self, batch: &Batch, hyper: &Hyper, ws: &mut Workspace)
        -> Result<StepOutput>;

    /// Forward-only evaluation.
    fn evaluate(&mut self, batch: &Batch, ws: &mut Workspace) -> Result<StepOutput>;

    fn trainable(&self) -> Vec<f32>;
    fn set_trainable(&mut self, p: &[f32]) -> Result<()>;
    fn num_trainable(&self) -> usize;
    fn name(&self) -> &'static str;

    /// Optimizer steps taken so far.
    fn steps(&self) -> usize;
}

/// AdamW state shared by both backends' Rust-side implementations.
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: usize,
}

impl AdamState {
    pub fn new(n: usize) -> Self {
        AdamState { m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }
}

/// Native backend: NativeModel + Rust AdamW, with all per-step state
/// (activations, gradients, parameter vector, optimizer moments)
/// preallocated and updated in place.
pub struct NativeBackend {
    pub model: NativeModel,
    pub opt: AdamState,
    /// Reusable activation/gradient buffers (keyed by batch shape).
    pub bufs: StepBuffers,
    /// Seed the model was constructed from (`Rng::new(seed)` +
    /// `NativeModel::from_backbone` re-derives all frozen adapter
    /// tensors). Recorded into exported artifacts; `None` for backends
    /// built through [`NativeBackend::new`] without a known seed — such
    /// backends cannot be exported (their frozen tensors could not be
    /// reconstructed), and the serve layer never spills them.
    pub build_seed: Option<u64>,
    /// Persistent flat parameter vector, kept in sync with the model.
    params: Vec<f32>,
    beta1: f64,
    beta2: f64,
    eps: f64,
}

impl NativeBackend {
    pub fn new(model: NativeModel) -> Self {
        let n = model.num_trainable();
        let params = model.trainable_flat();
        NativeBackend {
            model,
            opt: AdamState::new(n),
            bufs: StepBuffers::new(),
            build_seed: None,
            params,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }

    /// [`NativeBackend::new`] with the construction seed recorded, so the
    /// backend can be exported as a reconstructible artifact. The caller
    /// must have built `model` via `NativeModel::from_backbone` (plus an
    /// optional `set_head_classes`) on a fresh `Rng::new(seed)` — the
    /// sequence [`NativeBackend::from_artifact`] replays.
    pub fn with_seed(model: NativeModel, seed: u64) -> Self {
        let mut be = NativeBackend::new(model);
        be.build_seed = Some(seed);
        be
    }

    /// Whether this backend can be round-tripped through an artifact:
    /// its construction seed is known and it is not a pretraining-mode
    /// model. The serve layer only spills exportable backends.
    pub fn artifact_exportable(&self) -> bool {
        self.build_seed.is_some() && !self.model.train_embeddings
    }

    /// Build a backend for one adapter on a shared frozen backbone (the
    /// serve path): the frozen tensors stay `Arc`-shared with `backbone`
    /// and every sibling adapter; only adapter/head/optimizer state is
    /// owned. Identical construction to `NativeBackend::new` over
    /// `NativeModel::from_backbone` with a seed-`seed` Rng — so serve-side
    /// results are bit-comparable to a standalone run.
    pub fn for_adapter(backbone: &Arc<Backbone>, peft: &PeftConfig, seed: u64) -> NativeBackend {
        let mut rng = Rng::new(seed);
        NativeBackend::with_seed(NativeModel::from_backbone(backbone, peft, &mut rng), seed)
    }

    /// Snapshot this backend as a versioned, self-describing artifact (see
    /// [`crate::peft::artifact`]): per-module named parameter sections in
    /// interchange order (via the allocation-lean `params_into` path),
    /// then the encoder head, then the AdamW moments. Frozen tensors are
    /// *not* stored — they re-derive from `build_seed` + the config
    /// snapshot on a fingerprint-matching backbone, which is what keeps
    /// artifacts at Table 8 size.
    ///
    /// Errors when the backend is not [`NativeBackend::artifact_exportable`]:
    /// without a recorded construction seed the frozen tensors could not
    /// be reconstructed on import (the artifact would silently load wrong
    /// weights), and pretraining-mode models have trainable embeddings
    /// with no artifact section.
    pub fn to_artifact(&self, label: &str, backbone: &Backbone) -> Result<AdapterArtifact> {
        if self.model.train_embeddings {
            anyhow::bail!(
                "adapter artifacts cover adapter+head state only, not pretraining-mode models"
            );
        }
        let Some(seed) = self.build_seed else {
            anyhow::bail!(
                "backend has no recorded construction seed (built via NativeBackend::new); \
                 use with_seed/for_adapter so the artifact can re-derive frozen tensors"
            );
        };
        if label.len() > crate::peft::artifact::MAX_STR_LEN {
            // The reader rejects longer strings — exporting one would
            // produce an artifact that can never be loaded back.
            anyhow::bail!(
                "label is {} bytes; artifact strings are capped at {} bytes",
                label.len(),
                crate::peft::artifact::MAX_STR_LEN
            );
        }
        let mut sections = Vec::new();
        for (l, layer) in self.model.layers.iter().enumerate() {
            for (mk, op) in &layer.modules {
                if let ModuleOp::Adapted(a) = op {
                    for mut s in a.export_state() {
                        s.name = format!("l{l}.{}.{}", mk.name(), s.name);
                        sections.push(s);
                    }
                }
            }
        }
        if self.model.cfg.arch == Arch::Encoder {
            sections.push(Section::new("head.w", self.model.head_w.data.clone()));
            sections.push(Section::new("head.b", self.model.head_b.clone()));
        }
        sections.push(Section::new("adam.m", self.opt.m.clone()));
        sections.push(Section::new("adam.v", self.opt.v.clone()));
        Ok(AdapterArtifact {
            schema_version: SCHEMA_VERSION,
            method: self.model.peft.method,
            label: label.to_string(),
            model: self.model.cfg.clone(),
            peft: self.model.peft.clone(),
            seed,
            backbone_fp: backbone.fingerprint(),
            opt_step: self.opt.step as u64,
            inference_only: false,
            merged: false,
            f16_sections: false,
            sections,
        })
    }

    /// [`NativeBackend::to_artifact`] in inference-only form: the AdamW
    /// moment sections are dropped and the remaining parameter sections
    /// encode as f16 (~3× fewer bytes than the training artifact).
    /// Importing it serves and evaluates; resuming training restarts the
    /// optimizer cold, and the f16 narrowing perturbs parameters by
    /// ~1e-3 relative.
    pub fn to_inference_artifact(
        &self,
        label: &str,
        backbone: &Backbone,
    ) -> Result<AdapterArtifact> {
        Ok(self.to_artifact(label, backbone)?.to_inference_only())
    }

    /// Exact encoded size (bytes) of the artifact [`NativeBackend::to_artifact`]
    /// would produce, computed arithmetically from the section layout —
    /// no parameter copies or serialization. Mirrors the schema-2 writer
    /// (`tests/artifact.rs` pins the two against each other, so layout
    /// drift fails tests rather than silently skewing reports).
    pub fn artifact_encoded_len(&self, label: &str) -> usize {
        // Fixed header/trailer: magic 8, version 4, method 4, arch 4,
        // model ints 28, peft ints 20, flag bytes 4, svd 4, gamma 8,
        // n_modules 4, seed+fp+opt_step 24, artifact_flags 1, label
        // len-prefix 4, n_sections 4, checksum 8 = 129; plus one byte
        // per module tag and the label bytes. Each section adds 9 (name
        // + count prefixes + encoding byte) + name bytes + 4 bytes per
        // float (training artifacts are always f32-encoded).
        let mut n = 129 + self.model.peft.modules.len() + label.len();
        let section = |name_len: usize, floats: usize| 9 + name_len + 4 * floats;
        for (l, layer) in self.model.layers.iter().enumerate() {
            // "l{l}.{module}." prefix length.
            let digits = {
                let mut d = 1;
                let mut v = l;
                while v >= 10 {
                    v /= 10;
                    d += 1;
                }
                d
            };
            for (mk, op) in &layer.modules {
                if let ModuleOp::Adapted(a) = op {
                    let prefix = 1 + digits + 1 + mk.name().len() + 1;
                    for (name, len) in a.state_layout() {
                        n += section(prefix + name.len(), len);
                    }
                }
            }
        }
        if self.model.cfg.arch == Arch::Encoder {
            n += section("head.w".len(), self.model.head_w.data.len());
            n += section("head.b".len(), self.model.head_b.len());
        }
        n += section("adam.m".len(), self.opt.m.len());
        n += section("adam.v".len(), self.opt.v.len());
        n
    }

    /// Reconstruct a backend from an artifact on a *matching* backbone:
    /// validates the backbone fingerprint and model shape, re-derives the
    /// frozen adapter tensors from the recorded seed, then imports every
    /// parameter section (rotation methods re-run their Cayley–Neumann
    /// refresh from the imported θ) and the optimizer moments. The result
    /// is bit-identical to the exported backend on `forward`,
    /// `materialize`, and subsequent train steps.
    pub fn from_artifact(
        backbone: &Backbone,
        art: &AdapterArtifact,
    ) -> std::result::Result<NativeBackend, ArtifactError> {
        if art.merged {
            // Merged artifacts carry folded dense weights, not adapter
            // state — they load through `from_merged_artifact`.
            return Err(ArtifactError::ModelMismatch(
                "this is a merged-model artifact (psoft merge); load it with \
                 --merged / from_merged_artifact"
                    .to_string(),
            ));
        }
        let fp = backbone.fingerprint();
        if fp != art.backbone_fp {
            return Err(ArtifactError::BackboneMismatch {
                artifact: art.backbone_fp,
                backbone: fp,
            });
        }
        // The head may have been resized for a task; everything else must
        // match the backbone exactly.
        let mut want = art.model.clone();
        want.n_classes = backbone.cfg.n_classes;
        if want != backbone.cfg {
            return Err(ArtifactError::ModelMismatch(format!(
                "artifact model {:?} vs backbone {:?}",
                art.model, backbone.cfg
            )));
        }
        // Replays the exact construction sequence of the export side:
        // from_backbone (frozen tensors from per-module child streams),
        // then the optional head resize on the same parent rng.
        let mut rng = Rng::new(art.seed);
        let mut model = NativeModel::from_backbone(backbone, &art.peft, &mut rng);
        if model.cfg.arch == Arch::Encoder && art.model.n_classes != model.cfg.n_classes {
            model.set_head_classes(art.model.n_classes, &mut rng);
        }

        let mut idx = 0usize;
        let take = |idx: &mut usize, n: usize| -> std::result::Result<usize, ArtifactError> {
            let start = *idx;
            if start + n > art.sections.len() {
                return Err(ArtifactError::State(StateError::SectionCount {
                    expected: start + n,
                    found: art.sections.len(),
                }));
            }
            *idx += n;
            Ok(start)
        };
        for (l, layer) in model.layers.iter_mut().enumerate() {
            for (mk, op) in layer.modules.iter_mut() {
                if let ModuleOp::Adapted(a) = op {
                    let n = a.state_layout().len();
                    let start = take(&mut idx, n)?;
                    let secs = &art.sections[start..start + n];
                    let prefix = format!("l{l}.{}.", mk.name());
                    for s in secs {
                        if !s.name.starts_with(&prefix) {
                            return Err(ArtifactError::State(StateError::SectionName {
                                expected: format!("{prefix}*"),
                                found: s.name.clone(),
                            }));
                        }
                    }
                    a.import_state(secs)?;
                }
            }
        }
        if model.cfg.arch == Arch::Encoder {
            let start = take(&mut idx, 2)?;
            copy_named(&art.sections[start], "head.w", &mut model.head_w.data)?;
            copy_named(&art.sections[start + 1], "head.b", &mut model.head_b)?;
        }
        // Inference-only artifacts end here: no moment sections, and the
        // fresh backend keeps its zeroed AdamW state (cold resume).
        let adam = if art.inference_only { None } else { Some(take(&mut idx, 2)?) };
        if idx != art.sections.len() {
            return Err(ArtifactError::State(StateError::SectionCount {
                expected: idx,
                found: art.sections.len(),
            }));
        }
        let mut be = NativeBackend::new(model);
        if let Some(start) = adam {
            copy_named(&art.sections[start], "adam.m", &mut be.opt.m)?;
            copy_named(&art.sections[start + 1], "adam.v", &mut be.opt.v)?;
            be.opt.step = art.opt_step as usize;
        }
        be.build_seed = Some(art.seed);
        Ok(be)
    }

    /// The full optimizer step without constructing a `StepOutput`:
    /// forward + backward into `self.bufs`, global-norm clip, in-place
    /// AdamW on the persistent parameter vector, write-back into the
    /// model. Returns (loss, metric); per-example predictions are left in
    /// `self.bufs.preds`. This is the allocation-free hot path the
    /// counting-allocator test exercises.
    pub fn step_core(&mut self, batch: &Batch, hyper: &Hyper, ws: &mut Workspace) -> (f64, f64) {
        let (loss, metric) =
            native::train_grads_into(&self.model, batch, hyper.gamma_orth, &mut self.bufs, ws);
        let grads = &mut self.bufs.grads;

        // Global-norm clip (matches the artifact).
        let gnorm = grads.iter().map(|g| (*g as f64) * (*g as f64)).sum::<f64>().sqrt().max(1e-12);
        if gnorm > hyper.grad_clip {
            let s = (hyper.grad_clip / gnorm) as f32;
            for g in grads.iter_mut() {
                *g *= s;
            }
        }

        self.opt.step += 1;
        let t = self.opt.step as f64;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        let head_off = self.model.head_offset();
        for i in 0..self.params.len() {
            let g = grads[i] as f64;
            let m = self.beta1 * self.opt.m[i] as f64 + (1.0 - self.beta1) * g;
            let v = self.beta2 * self.opt.v[i] as f64 + (1.0 - self.beta2) * g * g;
            self.opt.m[i] = m as f32;
            self.opt.v[i] = v as f32;
            let update = (m / bc1) / ((v / bc2).sqrt() + self.eps);
            let lr = if i >= head_off { hyper.head_lr } else { hyper.lr };
            let p = self.params[i] as f64;
            self.params[i] = (p * (1.0 - lr * hyper.weight_decay) - lr * update) as f32;
        }
        self.model.set_trainable_flat(&self.params);
        (loss, metric)
    }

    /// Autoregressive generation on this backend's model — the serve
    /// layer's decode path as a standalone call (greedy argmax, or
    /// deterministic prompt-seeded sampling when `greedy` is false).
    /// Returns the emitted tokens; `cache` and `ws` stay warm for the
    /// next generation.
    pub fn generate(
        &self,
        prompt: &[i32],
        max_new_tokens: usize,
        greedy: bool,
        cache: &mut native::DecodeCache,
        ws: &mut Workspace,
    ) -> Vec<i32> {
        let mut out = Vec::with_capacity(max_new_tokens);
        native::generate_into(&self.model, prompt, max_new_tokens, greedy, cache, ws, &mut out);
        out
    }

    /// Dense merged twin of this backend: every adapted module folded
    /// into its effective weight ([`NativeModel::to_merged`], each fold
    /// validated against its method's pinned tolerance), fresh optimizer
    /// state. Forward/decode on the twin runs the plain pre-adapter
    /// kernels — the zero-adapter-overhead inference path the serve
    /// layer's merged mode dispatches. The fold is deterministic:
    /// folding the same backend twice yields bit-identical twins, which
    /// is what lets the serve layer drop a twin at spill time and
    /// re-derive it on reload.
    pub fn merged_twin(&self) -> Result<NativeBackend> {
        Ok(NativeBackend::new(self.model.to_merged()?))
    }

    /// Snapshot the **merged** form of this backend as an artifact: the
    /// folded dense weight of every adapted module (named `l{l}.{mod}.w`,
    /// always f32 — merged artifacts round-trip bit-exactly), plus the
    /// trained encoder head. Unlike [`NativeBackend::to_artifact`], no
    /// construction seed is needed to re-derive adapter tensors (the
    /// fold already erased them), so merged export works for any
    /// backend; the artifact is inherently inference-only (`merged` and
    /// `inference_only` flag bits both set).
    pub fn to_merged_artifact(
        &self,
        label: &str,
        backbone: &Backbone,
    ) -> Result<AdapterArtifact> {
        if self.model.train_embeddings {
            anyhow::bail!("merged artifacts cover adapter+head state only, not pretraining mode");
        }
        if label.len() > crate::peft::artifact::MAX_STR_LEN {
            anyhow::bail!(
                "label is {} bytes; artifact strings are capped at {} bytes",
                label.len(),
                crate::peft::artifact::MAX_STR_LEN
            );
        }
        let mut sections = Vec::new();
        for (l, layer) in self.model.layers.iter().enumerate() {
            for (mk, op) in &layer.modules {
                if let ModuleOp::Adapted(a) = op {
                    let folded = crate::peft::merge_adapter_checked(a.as_ref())
                        .map_err(|e| anyhow::anyhow!("folding l{l}.{}: {e}", mk.name()))?;
                    sections.push(Section::new(format!("l{l}.{}.w", mk.name()), folded.data));
                }
            }
        }
        if self.model.cfg.arch == Arch::Encoder {
            sections.push(Section::new("head.w", self.model.head_w.data.clone()));
            sections.push(Section::new("head.b", self.model.head_b.clone()));
        }
        Ok(AdapterArtifact {
            schema_version: SCHEMA_VERSION,
            method: self.model.peft.method,
            label: label.to_string(),
            model: self.model.cfg.clone(),
            peft: self.model.peft.clone(),
            seed: self.build_seed.unwrap_or(0),
            backbone_fp: backbone.fingerprint(),
            opt_step: 0,
            inference_only: true,
            merged: true,
            f16_sections: false,
            sections,
        })
    }

    /// Reconstruct the zero-adapter-overhead serving backend from a
    /// merged artifact on a fingerprint-matching backbone: the folded
    /// weights replace the corresponding frozen module weights
    /// ([`Backbone::with_module_weights`]), every module serves dense,
    /// and the encoder head is restored. The result's eval/decode is
    /// bit-identical to the [`NativeBackend::merged_twin`] that was
    /// exported (merged sections are always f32-encoded).
    pub fn from_merged_artifact(
        backbone: &Backbone,
        art: &AdapterArtifact,
    ) -> Result<NativeBackend> {
        anyhow::ensure!(
            art.merged,
            "artifact is not a merged-model artifact (run `psoft merge` to fold an adapter)"
        );
        let fp = backbone.fingerprint();
        anyhow::ensure!(
            fp == art.backbone_fp,
            "merged artifact was folded against backbone {:016x}, this backbone is {fp:016x}",
            art.backbone_fp
        );
        let mut want = art.model.clone();
        want.n_classes = backbone.cfg.n_classes;
        anyhow::ensure!(
            want == backbone.cfg,
            "artifact model {:?} vs backbone {:?}",
            art.model,
            backbone.cfg
        );
        // Split the trailing head sections from the folded weights.
        let n_head = if art.model.arch == Arch::Encoder { 2 } else { 0 };
        anyhow::ensure!(
            art.sections.len() >= n_head,
            "merged artifact has {} sections, need at least {n_head}",
            art.sections.len()
        );
        let (weight_secs, head_secs) = art.sections.split_at(art.sections.len() - n_head);
        let mut repl = Vec::with_capacity(weight_secs.len());
        for s in weight_secs {
            // Section names are "l{l}.{module}.w".
            let mut it = s.name.split('.');
            let (layer_tok, mod_tok, tail) = (it.next(), it.next(), it.next());
            let parsed = match (layer_tok, mod_tok, tail, it.next()) {
                (Some(lt), Some(mt), Some("w"), None) => lt
                    .strip_prefix('l')
                    .and_then(|d| d.parse::<usize>().ok())
                    .and_then(|l| {
                        ModuleKind::ALL.iter().find(|m| m.name() == mt).map(|m| (l, *m))
                    }),
                _ => None,
            };
            let Some((l, mk)) = parsed else {
                anyhow::bail!("unexpected merged-artifact section name {:?}", s.name);
            };
            let (din, dout) = art.model.module_shape(mk);
            anyhow::ensure!(
                s.data.len() == din * dout,
                "section {:?} has {} floats, want {din}x{dout}",
                s.name,
                s.data.len()
            );
            let mut w = crate::linalg::Mat::zeros(din, dout);
            w.data.copy_from_slice(&s.data);
            repl.push((l, mk, w));
        }
        let merged_bb = backbone.with_module_weights(repl)?;
        let mut peft = art.peft.clone();
        peft.modules = Vec::new();
        let mut rng = Rng::new(art.seed);
        let mut model = NativeModel::from_backbone(&merged_bb, &peft, &mut rng);
        if model.cfg.arch == Arch::Encoder {
            if art.model.n_classes != model.cfg.n_classes {
                model.set_head_classes(art.model.n_classes, &mut rng);
            }
            copy_named(&head_secs[0], "head.w", &mut model.head_w.data)?;
            copy_named(&head_secs[1], "head.b", &mut model.head_b)?;
        }
        Ok(NativeBackend::new(model))
    }
}

/// Copy one artifact section into a same-length destination after
/// validating its name — shared by the head/optimizer import paths.
fn copy_named(
    s: &Section,
    name: &str,
    dst: &mut [f32],
) -> std::result::Result<(), ArtifactError> {
    if s.name != name {
        return Err(ArtifactError::State(StateError::SectionName {
            expected: name.to_string(),
            found: s.name.clone(),
        }));
    }
    if s.data.len() != dst.len() {
        return Err(ArtifactError::State(StateError::SectionLen {
            name: s.name.clone(),
            expected: dst.len(),
            found: s.data.len(),
        }));
    }
    dst.copy_from_slice(&s.data);
    Ok(())
}

impl Backend for NativeBackend {
    fn train_step(
        &mut self,
        batch: &Batch,
        hyper: &Hyper,
        ws: &mut Workspace,
    ) -> Result<StepOutput> {
        let (loss, metric) = self.step_core(batch, hyper, ws);
        Ok(StepOutput { loss, metric, preds: self.bufs.preds.clone() })
    }

    fn evaluate(&mut self, batch: &Batch, ws: &mut Workspace) -> Result<StepOutput> {
        let (loss, metric) = native::evaluate_into(&self.model, batch, &mut self.bufs, ws);
        Ok(StepOutput { loss, metric, preds: self.bufs.preds.clone() })
    }

    fn trainable(&self) -> Vec<f32> {
        self.params.clone()
    }

    fn set_trainable(&mut self, p: &[f32]) -> Result<()> {
        self.model.set_trainable_flat(p);
        self.params.copy_from_slice(p);
        Ok(())
    }

    fn num_trainable(&self) -> usize {
        self.model.num_trainable()
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn steps(&self) -> usize {
        self.opt.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MethodKind, ModelConfig, ModuleKind, PeftConfig};
    use crate::model::native::Target;
    use crate::model::Backbone;
    use crate::util::rng::Rng;

    fn tiny() -> (NativeBackend, Batch) {
        let mut rng = Rng::new(401);
        let cfg = ModelConfig {
            arch: crate::config::Arch::Encoder,
            vocab_size: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            max_seq: 10,
            n_classes: 2,
        };
        let bb = Backbone::random(&cfg, &mut rng);
        let peft = PeftConfig::new(MethodKind::Psoft, 4)
            .with_modules(vec![ModuleKind::Q, ModuleKind::V]);
        let model = NativeModel::from_backbone(&bb, &peft, &mut rng);
        let tokens: Vec<i32> = (0..8 * 8).map(|_| rng.below(32) as i32).collect();
        let labels: Vec<usize> = (0..8).map(|b| (tokens[b * 8] as usize) % 2).collect();
        let batch = Batch {
            batch: 8,
            seq: 8,
            tokens,
            pad: vec![1.0; 64],
            target: Target::Class(labels),
        };
        (NativeBackend::new(model), batch)
    }

    #[test]
    fn adamw_reduces_loss() {
        let (mut be, batch) = tiny();
        let mut ws = Workspace::new();
        let hyper = Hyper { lr: 5e-3, head_lr: 5e-3, ..Default::default() };
        let first = be.train_step(&batch, &hyper, &mut ws).unwrap().loss;
        let mut last = first;
        for _ in 0..40 {
            last = be.train_step(&batch, &hyper, &mut ws).unwrap().loss;
        }
        assert!(last < first * 0.8, "{first} -> {last}");
        assert_eq!(be.steps(), 41);
    }

    #[test]
    fn grad_clip_bounds_update() {
        let (mut be, batch) = tiny();
        let mut ws = Workspace::new();
        let p0 = be.trainable();
        let hyper = Hyper { lr: 1.0, head_lr: 1.0, grad_clip: 1e-8, ..Default::default() };
        be.train_step(&batch, &hyper, &mut ws).unwrap();
        let p1 = be.trainable();
        // With a vanishing clip, first-step Adam update magnitude is tiny
        // relative to lr=1 unclipped behaviour.
        let delta: f64 =
            p0.iter().zip(&p1).map(|(a, b)| ((a - b) as f64).abs()).fold(0.0, f64::max);
        assert!(delta < 0.5, "max delta {delta}");
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let (mut be, batch) = tiny();
        let mut ws = Workspace::new();
        // Isolate decay: zero LR on updates is impossible (decay is scaled
        // by lr), so compare decay vs no-decay trajectories.
        let p0 = be.trainable();
        let hyper = Hyper { lr: 1e-3, head_lr: 1e-3, weight_decay: 0.5, ..Default::default() };
        be.train_step(&batch, &hyper, &mut ws).unwrap();
        let p_decay = be.trainable();
        let (mut be2, _) = tiny();
        be2.set_trainable(&p0).unwrap();
        let hyper2 = Hyper { lr: 1e-3, head_lr: 1e-3, weight_decay: 0.0, ..Default::default() };
        be2.train_step(&batch, &hyper2, &mut ws).unwrap();
        let p_plain = be2.trainable();
        let norm_decay: f64 = p_decay.iter().map(|v| (*v as f64).powi(2)).sum();
        let norm_plain: f64 = p_plain.iter().map(|v| (*v as f64).powi(2)).sum();
        assert!(norm_decay < norm_plain);
    }

    #[test]
    fn trainable_stays_in_sync_with_model() {
        let (mut be, batch) = tiny();
        let mut ws = Workspace::new();
        let hyper = Hyper { lr: 5e-3, head_lr: 5e-3, ..Default::default() };
        for _ in 0..3 {
            be.train_step(&batch, &hyper, &mut ws).unwrap();
        }
        // The persistent flat vector must match a fresh flatten of the
        // model after in-place updates.
        assert_eq!(be.trainable(), be.model.trainable_flat());
    }
}
