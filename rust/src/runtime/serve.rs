//! Multi-adapter serving core: one shared frozen backbone, N hot-swappable
//! adapters, a fair request scheduler over a fixed worker pool.
//!
//! # Architecture
//!
//! A [`ServeCore`] owns:
//!
//! - **One `Arc<Backbone>`** — the frozen pre-trained weights, loaded once.
//!   Every registered adapter's `NativeModel` references the *same* frozen
//!   tensors (see `model`: embeddings, dense modules and the LM head are
//!   `Arc`-shared), so hosting N adapters costs N × adapter-state, not
//!   N × model. **Backbone-sharing invariant:** nothing in the serve layer
//!   ever writes through those `Arc`s — adapters mutate only their own
//!   trainable state, so registration and eviction never touch the
//!   backbone and requests to different adapters can run concurrently.
//! - **A slot table** of registered adapters. Each slot owns the full
//!   per-adapter state: the [`NativeBackend`] (adapter tensors + optimizer
//!   moments + its warm [`StepBuffers`](crate::model::native::StepBuffers))
//!   and a bounded FIFO request queue.
//! - **A fixed worker pool.** Each worker owns a warm [`Workspace`] that
//!   serves whichever adapter it picks up (the pool is shape-keyed, so
//!   adapters of different ranks coexist without reallocation once warm).
//!   Workers orchestrate requests; large matmuls inside a request fan out
//!   over the process-wide persistent compute pool
//!   ([`util::threadpool::pool`](crate::util::threadpool::pool)), so warm
//!   serve and decode loops spawn no threads (pinned, together with the
//!   zero-allocation property, by `tests/serve_alloc.rs`).
//!
//! # Scheduling & admission
//!
//! All requests enter through ONE typed entry point —
//! [`ServeCore::submit`]`(id, Request, &Ticket, SubmitOptions)` — which
//! returns an [`Admission`] outcome instead of a bare error: `Admitted`
//! (enqueued, ticket armed), `Rejected(ServeError)` (hard failure — queue
//! full with observed depth, draining with remaining count, unknown
//! adapter, malformed request, shutdown), or `Shed(ShedReason)` (turned
//! away by load-shedding policy). A non-admitted request never touches
//! its ticket.
//!
//! **Dispatch tiers.** Round-robin over slots with queued work, at most
//! one worker per adapter at a time (adapter state is mutable), up to
//! `burst` consecutive requests per dispatch to amortize cache warmth.
//! With the default empty [`ServeOptions::tier_weights`] that is the
//! whole story — pure round-robin, bit-identical dispatch traces to the
//! pre-tier scheduler, which the fairness tests pin. With N weights
//! configured, each request carries a tier ([`SubmitOptions::priority`],
//! clamped to the last tier) and dispatch becomes weighted-fair over
//! tiers: tier t receives `tier_weights[t]` consecutive dispatch units
//! before the cursor advances, round-robin across adapters *within* a
//! tier, and a tier with no runnable work forfeits its remaining budget
//! (work-conserving — background tiers never block an idle scheduler).
//! A dispatch unit's tier is its queue-front job's tier; burst formation
//! never splits on tier boundaries.
//!
//! **Deadline clock & shed policy.** A request's optional deadline
//! ([`SubmitOptions::deadline`]) is relative to its submission instant
//! and bounds *completion*. Deadline-expired work is always failed
//! typed, never silently dropped: a zero deadline sheds at submit; a
//! queued request whose deadline passes is shed
//! ([`ShedReason::DeadlineExpired`]) by a sweep that runs before every
//! dispatch decision (lazily — an expired job deep in a queue sheds
//! when dispatch next looks at that queue; one already on a worker runs
//! to completion). With [`ServeOptions::shed_after_ms`] configured,
//! admission also sheds new work ([`ShedReason::QueueDelay`]) whenever
//! the adapter's queue-front request has already waited longer than the
//! bound — once queue delay is past the SLO, admitting more work only
//! converts future deadline misses into a longer queue. Per-adapter
//! queue depth stays capped (`queue_cap`); a full queue rejects with
//! [`ServeError::QueueFull`] carrying the observed depth — back-
//! pressure, not unbounded buffering.
//!
//! **Reload lane state machine.** A submit against a spilled adapter
//! marks its slot **Loading** and enqueues normally; it never runs the
//! reload itself. A worker picks the reload up as a dispatch unit:
//! `Loading (idle) → Loading (busy: artifact read + frozen re-derivation
//! off-lock, LRU victims spilled off-lock to make budget room) →
//! resident (queue dispatchable)`, or on failure `→ spilled (queued
//! requests fail ArtifactFailed; the artifact stays on disk and the next
//! submit retries)`. Dispatch never runs against a Loading slot (its
//! backend is absent by construction), and — the point of the lane —
//! the scheduler lock is *not* held across the reload I/O or SVD, so
//! every other adapter keeps dispatching while one warms up.
//!
//! # Generation requests (resumable multi-step jobs)
//!
//! [`Request::Generate`] runs an autoregressive decode on the adapter:
//! teacher-forced prefill over the prompt, then greedy (or deterministic
//! prompt-seeded sampled) continuation, one KV-cached
//! [`native::decode_step`] per position. Its lifecycle:
//!
//! 1. **Submit** validates against the shared backbone (decoder arch,
//!    non-empty in-vocab prompt, `prompt + max_new_tokens ≤ max_seq`) and
//!    enqueues a resumable `GenJob` — same queue, same depth cap as
//!    one-shot requests.
//! 2. **Dispatch** treats the generation as a *resumable* job: it is
//!    gathered — together with up to `decode_batch − 1` other
//!    generations at the queue front — into one lockstep **group** (see
//!    Continuous batching below) and advanced by at most `burst` decode
//!    steps — one dispatch consumes one burst quota whether it is
//!    `burst` one-shot requests or `burst` lockstep steps over a whole
//!    group — then re-enqueued at the *front* of its adapter's queue if
//!    unfinished. Round-robin fairness and burst caps therefore hold
//!    across adapters mid-generation; in-flight lanes transiently hold
//!    up to `decode_batch` queue slots beyond the submit-visible cap
//!    (the queue is pre-sized for them).
//! 3. **Streaming**: tokens emitted during a dispatch are appended to the
//!    ticket before the job completes — [`Ticket::wait_tokens`] /
//!    [`Ticket::with_tokens`] observe the stream mid-request;
//!    [`Ticket::wait`] returns (0.0, tokens_emitted) at completion.
//! 4. **K/V lanes** are pooled per worker and attached to a job on
//!    first dispatch; their K/V lives in fixed-size pages drawn from the
//!    workspace page pool and returned on completion (see Prefill &
//!    paging below), so the warm decode loop performs zero heap
//!    allocations — `tests/serve_alloc.rs`.
//! 5. **Eviction**: strict [`ServeCore::evict`] counts an in-flight
//!    generation as pending work (it cannot be "waited out");
//!    `evict_with(Reject)` fails it with [`ServeError::Evicted`],
//!    `evict_with(Drain)` serves it to completion.
//!
//! # Continuous batching (lockstep grouped decode)
//!
//! Dispatch is organized around **batch formation**: the maximal
//! same-kind run at an adapter's queue front becomes one dispatch unit.
//!
//! - **Generation groups.** When the queue front is a generation, up to
//!   [`ServeOptions::decode_batch`] consecutive generations are gathered
//!   into one **group**: their lanes (per-generation K/V rings —
//!   [`native::DecodeLane`]) join a worker's
//!   [`native::GroupDecodeCache`] and advance **in lockstep**, one
//!   batched `[g, d]` forward per token position, for up to `burst`
//!   steps. This amortizes every backbone/adapter weight read over `g`
//!   streams — the single biggest decode-throughput lever. Lanes **join
//!   and leave mid-flight**: a generation finishing inside a burst drops
//!   out of the lockstep immediately; unfinished lanes re-enqueue at the
//!   queue front as a block and are re-grouped — possibly with newly
//!   submitted generations — at their next dispatch.
//! - **Group lifecycle.** submit → queue → (join group, ≤ `burst`
//!   lockstep steps, leave group) → re-enqueue at front … → complete.
//!   A lane's K/V rings and stream cursor travel with its job between
//!   dispatches, so any worker can resume any generation.
//! - **Burst accounting.** One group dispatch consumes **one burst
//!   quota** for its adapter — whether it advances 1 lane or
//!   `decode_batch` lanes — and round-robin across adapters is
//!   unchanged; the fairness trace records one entry per group dispatch.
//!   Strict eviction counts **every lane** of an in-flight group as
//!   pending work.
//! - **Bit-invariance guarantee.** Every lane's token stream is
//!   bit-identical to the same generation run ungrouped (greedy or
//!   sampled), regardless of who it was batched with and across
//!   mid-flight join/leave: the step path is row-local end to end, each
//!   lane keeps its own ragged-length rings, and sampling uses per-lane
//!   prompt-seeded RNG streams. Pinned per PEFT method by
//!   `tests/decode.rs`; the warm grouped loop is allocation-free
//!   (`tests/serve_alloc.rs`).
//! - **Eval coalescing.** With [`ServeOptions::coalesce_eval`] (off by
//!   default), the same batch-formation seam merges a front run of
//!   same-adapter `Eval` requests with matching seq length and target
//!   kind — up to `decode_batch` of them — into ONE forward over their
//!   concatenation along the batch axis; per-request losses, metrics and
//!   predictions scatter back to their own tickets, bit-identical to
//!   uncoalesced evaluation (`native::evaluate_grouped_into`).
//!   FIFO order is preserved across kind boundaries: a batch never forms
//!   past the first job of a different kind, so results never reorder
//!   around a queued `Train` step.
//!
//! # Prefill & paging
//!
//! - **Paged K/V.** A lane's K/V is not a `[max_seq, d]` ring but a
//!   [`native::DecodeLane`] of per-layer page tables over fixed-size
//!   `[PAGE_ROWS, d]` pages drawn from the worker workspace's page pool
//!   (`linalg::workspace`, "Paged K/V"). Pages are acquired as positions
//!   are decoded and returned the moment a generation completes —
//!   resident decode memory tracks **active tokens** across the fleet,
//!   not lanes × max_seq, which is what lets hundreds of lanes coexist
//!   at bounded RSS (`benches/decode.rs` pins the scaling).
//! - **Chunked batched prefill.** A lane still feeding its prompt does
//!   not trickle one token per lockstep step: each group step it feeds
//!   up to [`ServeOptions::prefill_chunk`] prompt tokens through ONE
//!   batched `[p, d]` forward (`native::prefill_into`), interleaved with
//!   the decoding lanes' lockstep rows. A joining lane therefore reaches
//!   its first token in `ceil(prompt / prefill_chunk)` group steps — not
//!   `prompt` steps — while each step's stall for the decoding lanes is
//!   bounded by one chunk. Streams are bit-identical at every chunk
//!   size (chunk 1 reproduces the legacy schedule exactly).
//! - **Accounting.** A prefill chunk rides inside its group's dispatch:
//!   the group still consumes one burst quota, strict eviction counts a
//!   prefilling lane as in-flight work exactly like a decoding one
//!   (`gens_inflight`), and per-adapter [`AdapterStats::prefill_chunks`]
//!   / [`AdapterStats::prefill_tokens`] expose the prefill volume.
//!   Decode overflow past `max_seq` — unreachable through `submit`'s
//!   validation, but typed all the way down — fails the group's tickets
//!   with [`ServeError::DecodeOverflow`] (carrying each lane's prompt /
//!   max_new / max_seq numbers) instead of tripping worker panic
//!   containment.
//!
//! # Failure containment
//!
//! A panic in adapter compute is caught at the dispatch boundary (no
//! scheduler lock is ever held across compute, so none can be poisoned):
//! the offending adapter is retired — its in-flight and queued requests
//! fail with the typed [`ServeError::WorkerPanicked`] — and the worker
//! and every other adapter keep serving. Scheduler/ticket lock
//! acquisitions additionally recover from poisoning (a client thread
//! panicking mid-`wait` must not cascade into every later
//! `submit`/`evict`/`Drop`). Spill-path I/O failures are never silently
//! swallowed: a failed spill write leaves the adapter resident (state is
//! never lost to a "successful" evict over a failed write — artifact
//! writes go through a temp file + atomic rename), and failed spill-file
//! cleanup is logged.
//!
//! # Zero-allocation warm path
//!
//! A warm request round-trip — submit, dispatch, evaluate/train-step,
//! ticket completion, wait — performs **zero heap allocations**
//! (`tests/serve_alloc.rs`): queues are pre-sized `VecDeque`s, tickets are
//! reusable with pre-sized `preds` buffers, batches travel as `Arc<Batch>`
//! clones, and the compute runs the same warm-buffer hot path the trainer
//! uses.
//!
//! # Hot swap
//!
//! [`ServeCore::register`]/[`ServeCore::register_backend`] add adapters at
//! any time. Eviction semantics are explicit about pending work:
//! [`ServeCore::evict`] is *strict* — it refuses with
//! [`ServeError::PendingRequests`] (carrying the queued-request count)
//! when the adapter's queue is non-empty — while
//! [`ServeCore::evict_with`] takes an [`EvictMode`]:
//! [`EvictMode::Reject`] fails queued requests with
//! [`ServeError::Evicted`] and reports how many it failed,
//! [`EvictMode::Drain`] stops accepting new submissions, serves out the
//! queue, then evicts. Both wait out the in-flight burst and return the
//! owned [`NativeBackend`]. The backbone and every other adapter are
//! untouched throughout.
//!
//! # Persistence: checkpoint, restore, LRU evict-to-disk
//!
//! Adapters persist as versioned artifacts ([`crate::peft::artifact`]):
//!
//! - [`ServeCore::checkpoint`] snapshots a live adapter to a file without
//!   disturbing its queue.
//! - [`ServeCore::restore`] registers an adapter from a previously
//!   exported artifact (fingerprint-validated against this core's
//!   backbone).
//! - With `max_resident = N` ([`ServeOptions::max_resident`], `[serve]
//!   max_resident` in config), at most N adapters keep their state in
//!   memory: registering or reloading past the budget **spills** the
//!   least-recently-used idle adapter (empty queue, not running) to
//!   `spill_dir` and a later submit against a spilled adapter
//!   **transparently reloads** it — exact to the bit, including optimizer
//!   moments, because the artifact round-trip is exact. The budget is
//!   best-effort: busy or queued adapters are never spilled, so a burst
//!   across more than N adapters can transiently exceed it. Spills on
//!   the registration path run synchronously (registration already runs
//!   SVD init on the caller's thread); **reloads run on the async reload
//!   lane** — a worker executes the artifact read and frozen-tensor
//!   re-derivation (possibly an SVD) *off* the scheduler lock while the
//!   slot is marked Loading, so a cold adapter never stalls fleet
//!   dispatch (see Scheduling & admission above). The warm resident path
//!   is unaffected: a submit to a resident adapter only reads one
//!   `Option` and bumps an LRU counter (`tests/serve_alloc.rs` still
//!   pins zero allocations).
//!
//! # Quantized serving
//!
//! The shared frozen backbone — the dominant resident cost of a
//! multi-adapter fleet — can be held **block-quantized to int8**
//! ([`crate::linalg::QuantMat`], per-64-element symmetric scales) instead
//! of f32: `psoft serve --backbone-dtype int8`, or `backbone_dtype =
//! "int8"` under `[model]` in the config. Quantization happens once at
//! load time ([`Backbone::to_dtype`](crate::model::Backbone::to_dtype));
//! every registered adapter then shares the same quantized tensors, so
//! the ~3.75× shrink of frozen bytes applies to the whole fleet at once.
//! Forward/decode matmuls against quantized weights run the
//! dequant-fused kernels in [`crate::linalg::quant`] — blocks dequantize
//! in registers inside the cache-tiled loop, no f32 materialization of
//! the backbone ever exists. Adapter state, optimizer moments and the
//! trainable head stay f32, so train-on-serve keeps full precision;
//! only frozen-weight reads see quantization error (eval-loss budget
//! pinned by `tests/quant.rs`). The default is f32 and that path is
//! bit-identical to the pre-quantization build — [`ServeCore`] stores a
//! [`SharedMat`](crate::model::SharedMat)-backed backbone either way,
//! and the f32 arm dispatches to the exact same kernels as before.
//! [`ServeReport`](crate::coordinator::report::ServeReport) surfaces
//! the resident footprint (`shared_frozen_mib`, `backbone_dtype`) so
//! benches and the CI gate can hold the int8/f32 ratio down.
//!
//! # Merged serving
//!
//! An adapter whose training has converged pays structured-adapter
//! arithmetic on every token it serves — rotations, low-rank updates,
//! magnitude rescales — even though its weights no longer change. Merged
//! mode removes that tax: [`ServeCore::promote`] folds the adapter's
//! effective weights into a **dense merged twin**
//! ([`NativeBackend::merged_twin`]) whose forward/decode path runs the
//! plain pre-adapter kernels, then installs the twin next to the adapted
//! backend in the slot. Subsequent eval and generate dispatches pick the
//! twin ([`AdapterStats::merged_tokens`] counts the tokens it emits);
//! train submits are refused typed with [`ServeError::MergedAdapter`]
//! because a train step needs the adapted parameterization —
//! [`ServeCore::demote`] drops the twin and restores the adapted path.
//! The adapted backend stays the slot's source of truth throughout:
//! spill writes the *adapted* artifact and drops the twin (fold
//! determinism re-derives it bit-identically), and the transparent
//! reload lane re-promotes a merged slot off-lock before serving resumes.
//! `ServeOptions::merge_resident` (`[serve] merge_resident`, `--merge`)
//! promotes every adapter at registration for inference-only fleets.
//! The fold itself runs **off the scheduler lock** — promotion of one
//! adapter never stalls dispatch for the rest of the fleet.

use crate::config::PeftConfig;
use crate::linalg::Workspace;
use crate::model::native::{self, Batch, DecodeLane, GroupDecodeCache, Target};
use crate::model::Backbone;
use crate::peft::artifact::AdapterArtifact;
use crate::peft::AdapterId;
use crate::runtime::{Hyper, NativeBackend};
use crate::util::stats::QuantileSketch;
use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, Instant};

/// Lock acquisition that survives poisoning. A worker panic is already
/// contained at the dispatch boundary (see `worker_loop`), but a *client*
/// thread can still panic while holding a ticket or scheduler lock — in
/// that case the protected data is a plain state machine whose every
/// transition is valid, so we recover the guard instead of letting one
/// panic cascade through every later `lock().unwrap()` in
/// `submit`/`evict`/`Drop`.
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Condvar wait with the same poison recovery as [`relock`].
fn rewait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// What a request asks the adapter to do.
#[derive(Clone, Copy, Debug)]
pub enum ReqKind {
    /// Forward-only evaluation of the batch.
    Eval,
    /// One fine-tuning optimizer step on the batch.
    Train(Hyper),
}

/// A full serve request: the two one-shot batch kinds plus resumable
/// autoregressive generation. Every variant enters through the one
/// typed entry point, [`ServeCore::submit`].
#[derive(Clone, Debug)]
pub enum Request {
    /// Forward-only evaluation of the batch.
    Eval { batch: Arc<Batch> },
    /// One fine-tuning optimizer step on the batch.
    Train { batch: Arc<Batch>, hyper: Hyper },
    /// Autoregressive decode: teacher-forced prefill over `prompt`, then
    /// emit up to `max_new_tokens` tokens (greedy argmax, or a
    /// deterministic prompt-seeded categorical sample). Scheduled as a
    /// **resumable multi-step job**: each dispatch advances it by at most
    /// `burst` decode steps before the round-robin cursor moves on, so
    /// fairness and burst caps hold across adapters mid-generation.
    /// Tokens stream into the ticket as they are emitted
    /// ([`Ticket::wait_tokens`] / [`Ticket::with_tokens`]).
    Generate { prompt: Arc<Vec<i32>>, max_new_tokens: usize, greedy: bool },
}

/// Serve-layer errors. `Copy` so completed tickets can carry one without
/// allocating. Every admission failure is a distinct variant carrying
/// the state that caused it (observed queue depth, remaining drain
/// count, shed reason) — callers branch on the variant, not on a log
/// line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The adapter's queue is at its depth cap — backpressure; retry
    /// later. Carries the observed depth and the configured cap.
    QueueFull { depth: usize, cap: usize },
    /// No live adapter with this id.
    UnknownAdapter,
    /// The adapter was evicted before the request ran.
    Evicted,
    /// An `evict_with(Drain)` owns this adapter: it is serving out its
    /// queue (`queued` requests left when the submit was refused) and
    /// accepts no new work.
    Draining { queued: usize },
    /// The request was turned away by load-shedding policy (deadline
    /// expiry or queue-delay admission control) — the `Result`-shaped
    /// form of [`Admission::Shed`], and the error a queued request's
    /// ticket carries when its deadline expires before dispatch.
    Shed(ShedReason),
    /// Strict [`ServeCore::evict`] refused: the adapter still has this
    /// many queued requests. Use [`ServeCore::evict_with`] to drain or
    /// reject them explicitly.
    PendingRequests(usize),
    /// Spilling or reloading the adapter's on-disk artifact failed.
    ArtifactFailed,
    /// The request is malformed for this core's backbone (generation on
    /// an encoder, empty prompt, or an out-of-vocab prompt token).
    InvalidRequest,
    /// The generation cannot fit the model's context window. Carries the
    /// numbers a client needs to retry sensibly: the prompt length, the
    /// requested continuation, and the window they must fit in
    /// (mirroring `native::DecodeError::PastMaxSeq`). Returned at submit
    /// when `prompt + max_new > max_seq`, and kept typed all the way
    /// down the decode path so an overflow surfacing mid-group can never
    /// masquerade as a worker panic.
    DecodeOverflow { prompt: usize, max_new: usize, max_seq: usize },
    /// The adapter is serving in **merged mode** (its adapted weights are
    /// folded into a dense twin — see the module docs' Merged serving
    /// section): train steps need the adapted parameterization, so train
    /// submits are refused typed. [`ServeCore::demote`] restores the
    /// adapted path, after which training is accepted again.
    MergedAdapter,
    /// The worker servicing this request panicked. The panic is contained
    /// (caught at the dispatch boundary, never across a held scheduler
    /// lock): the adapter whose compute panicked is retired — its
    /// in-flight and queued requests all fail with this error — and the
    /// worker, pool, and every other adapter keep serving.
    WorkerPanicked,
    /// The core is shutting down.
    ShuttingDown,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::QueueFull { depth, cap } => {
                write!(f, "adapter queue at depth cap ({depth}/{cap}); retry later")
            }
            ServeError::UnknownAdapter => f.write_str("unknown adapter id"),
            ServeError::Evicted => f.write_str("adapter evicted before the request ran"),
            ServeError::Draining { queued } => write!(
                f,
                "adapter is draining ({queued} queued request(s) left); no new submissions"
            ),
            ServeError::Shed(reason) => write!(f, "request shed: {reason}"),
            ServeError::PendingRequests(n) => write!(
                f,
                "adapter has {n} pending request(s); evict_with(Drain) or evict_with(Reject) \
                 to resolve them explicitly"
            ),
            ServeError::ArtifactFailed => {
                f.write_str("adapter artifact spill/reload failed (see warning log)")
            }
            ServeError::InvalidRequest => {
                f.write_str("request is malformed for this backbone (arch/prompt/length)")
            }
            ServeError::DecodeOverflow { prompt, max_new, max_seq } => write!(
                f,
                "generation of {prompt} prompt + {max_new} new tokens cannot fit the \
                 model's context window (max_seq {max_seq})"
            ),
            ServeError::MergedAdapter => f.write_str(
                "adapter is serving in merged mode (train needs the adapted weights); \
                 demote it before submitting train steps",
            ),
            ServeError::WorkerPanicked => {
                f.write_str("serve worker panicked while running this adapter; adapter retired")
            }
            ServeError::ShuttingDown => f.write_str("serve core shutting down"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Why a request was shed by admission control ([`Admission::Shed`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// The request's deadline passed — at submission (zero deadline) or
    /// while it waited in the queue, before dispatch picked it up.
    DeadlineExpired,
    /// The adapter's queue-front request has already waited longer than
    /// the configured [`ServeOptions::shed_after_ms`] bound: queue delay
    /// is past the SLO, so new work is turned away immediately rather
    /// than joining a doomed wait.
    QueueDelay,
}

impl fmt::Display for ShedReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShedReason::DeadlineExpired => f.write_str("deadline expired before dispatch"),
            ShedReason::QueueDelay => f.write_str("queue delay past the shed_after bound"),
        }
    }
}

/// Typed admission outcome of [`ServeCore::submit`]. `Copy` and
/// allocation-free so checking it keeps the warm submit path
/// zero-alloc.
#[must_use = "check the admission outcome: a Rejected/Shed request never completes its ticket"]
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Admission {
    /// Enqueued: the ticket was re-armed and will complete (or fail
    /// typed) exactly once.
    Admitted,
    /// Hard admission failure (queue full with observed depth, unknown
    /// or draining adapter, malformed request, shutdown). The ticket's
    /// previous completion is left intact.
    Rejected(ServeError),
    /// Turned away by load-shedding policy. The ticket is untouched.
    Shed(ShedReason),
}

impl Admission {
    pub fn is_admitted(self) -> bool {
        matches!(self, Admission::Admitted)
    }

    /// Collapse into a `Result` — shed outcomes map to
    /// [`ServeError::Shed`], for call sites that propagate with `?`
    /// rather than branching on the admission outcome.
    pub fn into_result(self) -> Result<(), ServeError> {
        match self {
            Admission::Admitted => Ok(()),
            Admission::Rejected(e) => Err(e),
            Admission::Shed(r) => Err(ServeError::Shed(r)),
        }
    }
}

/// Per-request scheduling options for [`ServeCore::submit`]: builder-
/// style setters over a `Default` base. `Copy` and allocation-free so a
/// warm submit stays zero-alloc.
///
/// ```
/// # use psoft::runtime::serve::SubmitOptions;
/// # use std::time::Duration;
/// let opts = SubmitOptions::default()
///     .with_priority(1)
///     .with_deadline(Duration::from_millis(250));
/// # assert_eq!(opts.priority, 1);
/// ```
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SubmitOptions {
    /// Scheduling tier, 0 = highest priority. Meaningful only when the
    /// core runs with non-empty [`ServeOptions::tier_weights`]; values
    /// past the last configured tier clamp to it. Ignored under the
    /// default pure round-robin scheduler.
    pub priority: usize,
    /// Relative completion deadline, measured from the submission
    /// instant. Expired-before-dispatch requests are **shed** with
    /// [`ShedReason::DeadlineExpired`] — failed typed, never silently
    /// dropped. `None` (default) = no deadline.
    pub deadline: Option<Duration>,
}

impl SubmitOptions {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the scheduling tier (see [`SubmitOptions::priority`]).
    pub fn with_priority(mut self, priority: usize) -> Self {
        self.priority = priority;
        self
    }

    /// Set the completion deadline, relative to submission.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// What to do with queued requests when evicting an adapter
/// ([`ServeCore::evict_with`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictMode {
    /// Fail every queued request with [`ServeError::Evicted`] immediately;
    /// the eviction result reports how many were failed.
    Reject,
    /// Stop accepting new submissions, serve the queue to completion, then
    /// evict (reported pending count is therefore 0). Like
    /// [`ServeCore::drain`], this unpauses a `start_paused` core for the
    /// whole fleet — the queue could never empty otherwise — and the core
    /// stays unpaused afterwards.
    Drain,
}

/// Per-adapter service counters (cheap plain integers plus fixed-size
/// quantile sketches — updated without allocation on the warm path).
#[derive(Clone, Copy, Debug, Default)]
pub struct AdapterStats {
    /// Requests completed (eval + train).
    pub processed: u64,
    /// Optimizer steps among them.
    pub train_steps: u64,
    /// Submissions rejected at the queue-depth cap.
    pub rejected: u64,
    /// Requests shed by admission control or the deadline sweep
    /// ([`Admission::Shed`] / [`ServeError::Shed`]).
    pub shed: u64,
    /// Σ enqueue→completion nanoseconds over processed requests.
    pub total_latency_ns: u64,
    /// Worst single enqueue→completion latency.
    pub max_latency_ns: u64,
    /// Σ on-worker service nanoseconds (compute only, no queueing).
    pub service_ns: u64,
    /// Tokens emitted by completed-or-in-progress generation requests.
    pub tokens_generated: u64,
    /// Batched dispatch units (generation groups + coalesced eval
    /// groups).
    pub group_dispatches: u64,
    /// Σ lanes/requests across those group dispatches
    /// (`group_lanes / group_dispatches` = mean group size).
    pub group_lanes: u64,
    /// Largest single group dispatched for this adapter.
    pub max_group_size: u64,
    /// Streaming time-to-first-token sketch (nanoseconds): one sample
    /// per request, recorded when its first result lands — first emitted
    /// token for generations, enqueue→completion latency for one-shot
    /// eval/train requests.
    pub ttft: QuantileSketch,
    /// TTFT split by scheduling tier (nanoseconds): index 0 samples
    /// tier-0 ("interactive") requests, index 1 every lower tier
    /// ("batch"). An SLO gate reads the interactive sketch alone —
    /// averaging the tiers together is exactly what a latency SLO must
    /// not do.
    pub ttft_tiered: [QuantileSketch; 2],
    /// Streaming per-token decode latency sketch (nanoseconds per
    /// emitted token): one sample per generation dispatch (group service
    /// time / tokens emitted).
    pub tok_latency: QuantileSketch,
    /// Chunked-prefill dispatch units consumed by this adapter's
    /// generations (one per prompt-phase lane per lockstep group step).
    pub prefill_chunks: u64,
    /// Prompt tokens fed through the batched `[p, d]` prefill path.
    pub prefill_tokens: u64,
    /// Adapter currently serving in merged mode (dense folded twin
    /// dispatched for eval/generate; train refused).
    pub merged: bool,
    /// Tokens emitted by dispatches that ran on the merged twin (subset
    /// of `tokens_generated`; the difference ran the adapted path).
    pub merged_tokens: u64,
}

impl AdapterStats {
    pub fn mean_latency_ms(&self) -> f64 {
        if self.processed == 0 {
            0.0
        } else {
            self.total_latency_ns as f64 / self.processed as f64 / 1e6
        }
    }

    pub fn max_latency_ms(&self) -> f64 {
        self.max_latency_ns as f64 / 1e6
    }

    pub fn mean_service_ms(&self) -> f64 {
        if self.processed == 0 {
            0.0
        } else {
            self.service_ns as f64 / self.processed as f64 / 1e6
        }
    }

    /// Mean lanes per batched dispatch (0.0 when nothing batched) — the
    /// continuous-batching efficiency figure the serve reports surface.
    pub fn mean_group_size(&self) -> f64 {
        if self.group_dispatches == 0 {
            0.0
        } else {
            self.group_lanes as f64 / self.group_dispatches as f64
        }
    }

    /// Time-to-first-token quantile in milliseconds (`q` in [0, 1];
    /// 0.0 when no samples yet).
    pub fn ttft_ms(&self, q: f64) -> f64 {
        self.ttft.quantile(q) / 1e6
    }

    /// Per-token decode latency quantile in milliseconds.
    pub fn tok_latency_ms(&self, q: f64) -> f64 {
        self.tok_latency.quantile(q) / 1e6
    }

    /// Tier-split TTFT quantile in milliseconds: `tier` 0 reads the
    /// interactive sketch, any other value the batch sketch.
    pub fn ttft_tier_ms(&self, tier: usize, q: f64) -> f64 {
        self.ttft_tiered[tier.min(1)].quantile(q) / 1e6
    }
}

/// Scheduler knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Worker threads (≥ 1). Each owns a warm `Workspace`.
    pub workers: usize,
    /// Per-adapter queue depth cap (≥ 1); submissions beyond it get
    /// [`ServeError::QueueFull`].
    pub queue_cap: usize,
    /// Max consecutive requests one dispatch takes from a single adapter
    /// (≥ 1) before the round-robin cursor moves on.
    pub burst: usize,
    /// Capacity of the scheduling trace (dispatch order of adapter ids,
    /// recorded until full). 0 disables tracing; tests use it to pin
    /// round-robin fairness.
    pub trace_cap: usize,
    /// Start with dispatch paused (tests enqueue a deterministic backlog,
    /// then [`ServeCore::resume`]).
    pub start_paused: bool,
    /// Resident-adapter budget: past this many in-memory adapters, the
    /// least-recently-used idle adapter spills to disk and reloads
    /// transparently on its next submit. 0 disables eviction (default).
    pub max_resident: usize,
    /// Directory for spilled artifacts. `None` (default) picks a unique
    /// per-core directory under the system temp dir.
    pub spill_dir: Option<PathBuf>,
    /// Continuous-batching width (≥ 1): one dispatch gathers up to this
    /// many same-adapter generations into a lockstep decode group, and
    /// caps how many queued evals one coalesced dispatch merges. 1
    /// disables grouping (every generation decodes alone).
    pub decode_batch: usize,
    /// Merge queued same-adapter eval requests (matching seq length and
    /// target kind) into one batched forward, scattering per-request
    /// results back to their tickets. Off by default.
    pub coalesce_eval: bool,
    /// Weighted-fair dispatch tiers. Empty (default) = pure round-robin,
    /// bit-identical to the pre-tier scheduler. With N weights, tier t
    /// gets `tier_weights[t]` consecutive dispatch units before the tier
    /// cursor advances; [`SubmitOptions::priority`] selects a request's
    /// tier (clamped to N − 1); a tier with no runnable work forfeits
    /// its remaining budget.
    pub tier_weights: Vec<u64>,
    /// Queue-delay admission shedding: when > 0 and an adapter's
    /// queue-front request has already waited more than this many
    /// milliseconds, new submissions to that adapter are shed with
    /// [`ShedReason::QueueDelay`]. 0 (default) disables.
    pub shed_after_ms: u64,
    /// Prompt tokens a joining generation feeds per lockstep group step
    /// through the batched prefill path (clamped to ≥ 1; 1 reproduces
    /// the legacy one-token-per-step schedule). Streams are
    /// bit-identical at every value — only the step schedule and the
    /// per-step group stall change. Defaults to one full K/V page
    /// (`native::DEFAULT_PREFILL_CHUNK`).
    pub prefill_chunk: usize,
    /// Promote every adapter to merged mode at registration (and after
    /// every transparent reload): the fleet serves dense folded twins,
    /// train submits are refused typed. Off by default — see the module
    /// docs' Merged serving section.
    pub merge_resident: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            workers: crate::util::threadpool::default_parallelism(),
            queue_cap: 32,
            burst: 4,
            trace_cap: 0,
            start_paused: false,
            max_resident: 0,
            spill_dir: None,
            decode_batch: 4,
            coalesce_eval: false,
            tier_weights: Vec::new(),
            shed_after_ms: 0,
            prefill_chunk: native::DEFAULT_PREFILL_CHUNK,
            merge_resident: false,
        }
    }
}

/// `[serve]` config section → scheduler knobs (remaining fields keep
/// their defaults).
impl From<crate::config::ServeConfig> for ServeOptions {
    fn from(sc: crate::config::ServeConfig) -> ServeOptions {
        ServeOptions {
            workers: sc.workers,
            queue_cap: sc.queue_cap,
            burst: sc.burst,
            max_resident: sc.max_resident,
            decode_batch: sc.decode_batch,
            coalesce_eval: sc.coalesce_eval,
            tier_weights: sc.tier_weights.iter().map(|&w| w as u64).collect(),
            shed_after_ms: sc.shed_after_ms,
            prefill_chunk: sc.prefill_chunk,
            merge_resident: sc.merge_resident,
            ..ServeOptions::default()
        }
    }
}

struct TicketState {
    done: bool,
    loss: f64,
    metric: f64,
    preds: Vec<f32>,
    /// Generation requests stream their emitted tokens here (appended
    /// after every dispatch burst, before the request completes).
    tokens: Vec<i32>,
    error: Option<ServeError>,
    /// Re-arm generation counter. `arm()` bumps it (and notifies), so a
    /// `wait_tokens` caller that raced a failure + re-arm observes the
    /// counter change instead of re-sleeping on a cleared token buffer —
    /// the lost-wakeup window the counter closes.
    gen: u64,
}

struct TicketInner {
    state: Mutex<TicketState>,
    cv: Condvar,
}

/// Reusable completion handle for one in-flight request.
///
/// A ticket may carry **one outstanding request at a time**; `submit`
/// re-arms it. `preds` and `tokens` capacity is pre-sized at construction
/// so warm completions never allocate. For generation requests the ticket
/// doubles as the **stream**: emitted tokens appear in `tokens` while the
/// request is still running ([`Ticket::wait_tokens`] blocks for the next
/// batch, [`Ticket::with_tokens`] reads what has arrived).
#[derive(Clone)]
pub struct Ticket {
    inner: Arc<TicketInner>,
}

impl Ticket {
    /// `capacity` sizes the per-example prediction buffer *and* the
    /// generated-token stream buffer (use the batch size for eval/train
    /// tickets, `max_new_tokens` for generation tickets).
    pub fn new(capacity: usize) -> Ticket {
        Ticket {
            inner: Arc::new(TicketInner {
                state: Mutex::new(TicketState {
                    done: false,
                    loss: f64::NAN,
                    metric: f64::NAN,
                    preds: Vec::with_capacity(capacity),
                    tokens: Vec::with_capacity(capacity),
                    error: None,
                    gen: 0,
                }),
                cv: Condvar::new(),
            }),
        }
    }

    /// Block until the request completes; returns (loss, metric). For
    /// generation requests the metric is the number of emitted tokens
    /// (and the loss 0.0).
    pub fn wait(&self) -> Result<(f64, f64), ServeError> {
        let mut ts = relock(&self.inner.state);
        while !ts.done {
            ts = rewait(&self.inner.cv, ts);
        }
        match ts.error {
            Some(e) => Err(e),
            None => Ok((ts.loss, ts.metric)),
        }
    }

    /// Completed request finished?
    pub fn is_done(&self) -> bool {
        relock(&self.inner.state).done
    }

    /// Borrow the per-example predictions of the completed request
    /// without copying them out.
    pub fn with_preds<R>(&self, f: impl FnOnce(&[f32]) -> R) -> R {
        let ts = relock(&self.inner.state);
        f(&ts.preds)
    }

    /// Borrow the tokens a generation request has streamed so far (valid
    /// mid-request; the slice only ever grows until completion).
    pub fn with_tokens<R>(&self, f: impl FnOnce(&[i32]) -> R) -> R {
        let ts = relock(&self.inner.state);
        f(&ts.tokens)
    }

    /// Tokens streamed so far.
    pub fn tokens_ready(&self) -> usize {
        relock(&self.inner.state).tokens.len()
    }

    /// Block until at least `n` tokens have streamed, the request
    /// finished, or the ticket was re-armed for a new request; returns
    /// how many tokens are available (which may be less than `n` only
    /// when the generation completed, failed early, or the ticket moved
    /// on to a new request). The generation-counter re-check before
    /// every re-sleep closes the lost-wakeup window where a worker
    /// panic fails the request and a re-submit clears the token buffer
    /// between this thread's wakeup and its next wait.
    pub fn wait_tokens(&self, n: usize) -> usize {
        let mut ts = relock(&self.inner.state);
        let gen0 = ts.gen;
        while ts.tokens.len() < n && !ts.done && ts.gen == gen0 {
            ts = rewait(&self.inner.cv, ts);
        }
        ts.tokens.len()
    }

    fn arm(&self) {
        let mut ts = relock(&self.inner.state);
        ts.done = false;
        ts.error = None;
        ts.preds.clear();
        ts.tokens.clear();
        ts.gen = ts.gen.wrapping_add(1);
        drop(ts);
        // Wake stale `wait_tokens` waiters from the previous request so
        // they observe the generation change instead of sleeping forever
        // on a buffer that was just cleared.
        self.inner.cv.notify_all();
    }
}

fn complete(ticket: &TicketInner, loss: f64, metric: f64, preds: &[f32]) {
    let mut ts = relock(&ticket.state);
    ts.loss = loss;
    ts.metric = metric;
    ts.preds.clear();
    ts.preds.extend_from_slice(preds);
    ts.error = None;
    ts.done = true;
    drop(ts);
    ticket.cv.notify_all();
}

/// Stream freshly emitted tokens into the ticket (mid-generation — the
/// request is not yet done) and wake `wait_tokens` callers.
fn stream_tokens(ticket: &TicketInner, tokens: &[i32]) {
    let mut ts = relock(&ticket.state);
    ts.tokens.extend_from_slice(tokens);
    drop(ts);
    ticket.cv.notify_all();
}

/// Complete a generation request: loss 0.0, metric = emitted tokens.
fn complete_gen(ticket: &TicketInner) {
    let mut ts = relock(&ticket.state);
    ts.loss = 0.0;
    ts.metric = ts.tokens.len() as f64;
    ts.preds.clear();
    ts.error = None;
    ts.done = true;
    drop(ts);
    ticket.cv.notify_all();
}

fn fail(ticket: &TicketInner, err: ServeError) {
    let mut ts = relock(&ticket.state);
    ts.error = Some(err);
    ts.done = true;
    drop(ts);
    ticket.cv.notify_all();
}

/// A resumable generation in flight: consumed prompt prefix, emitted
/// tail, and the (worker-pooled) per-lane K/V rings it decodes into.
/// Lives inside the slot queue between dispatches, so fairness is
/// preserved mid-generation; at each dispatch it **joins a lockstep
/// group** with whatever same-adapter generations are at the queue front
/// (see the module docs' Continuous batching section).
struct GenJob {
    prompt: Arc<Vec<i32>>,
    max_new_tokens: usize,
    greedy: bool,
    /// The resumable decode cursor — the SAME bookkeeping
    /// `native::generate_into` drives to completion (prompt cursor,
    /// last token, prompt-seeded RNG), moved into the group for each
    /// burst, so serve-side streams are bit-identical to direct decodes
    /// by construction.
    stream: native::DecodeStream,
    /// Per-lane paged K/V; taken from the worker's lane pool on first
    /// dispatch, carried here between dispatches (any worker can resume
    /// the lane), and returned to a pool — pages freed — on completion.
    lane: Option<DecodeLane>,
    /// Tokens emitted across all dispatches so far — 0 until the first
    /// token lands, which is the TTFT sampling point.
    emitted: usize,
}

// The Gen variant is deliberately inline (not boxed): a queued job is a
// few hundred bytes of struct, and keeping it flat means a warm
// generation submit performs zero heap allocations.
#[allow(clippy::large_enum_variant)]
enum JobKind {
    Batch { batch: Arc<Batch>, req: ReqKind },
    Gen(GenJob),
}

struct Job {
    kind: JobKind,
    ticket: Arc<TicketInner>,
    enqueued: Instant,
    /// Scheduling tier ([`SubmitOptions::priority`]); ignored under the
    /// default pure round-robin scheduler.
    tier: usize,
    /// Absolute completion deadline (submission instant + the relative
    /// [`SubmitOptions::deadline`]); `None` = no deadline.
    deadline: Option<Instant>,
}

struct Slot {
    id: AdapterId,
    /// Human-readable label (method/rank) for reporting.
    label: String,
    /// None while a worker runs this adapter, while the state is spilled
    /// to disk, or after eviction.
    backend: Option<NativeBackend>,
    queue: VecDeque<Job>,
    busy: bool,
    live: bool,
    /// Generation lanes currently on a worker (in-flight, not queued).
    /// Strict [`ServeCore::evict`] counts **every lane** of a dispatched
    /// group as pending work: unlike a one-shot burst, unfinished
    /// generations cannot be "waited out" without either failing them or
    /// draining.
    gens_inflight: usize,
    /// Evict-with-drain in progress: new submissions are refused while the
    /// queue serves out.
    draining: bool,
    /// Spilled-to-disk artifact. Invariant for live slots: `spill` is
    /// `Some` iff the state is neither resident (`backend`) nor running
    /// compute (`busy` with `!loading`). A spilled slot with queued work
    /// is `loading` — awaiting the async reload lane.
    spill: Option<PathBuf>,
    /// Reload-lane flag: a submit against a spilled adapter marks the
    /// slot Loading and enqueues; a worker picks the reload up as a
    /// dispatch unit and runs the artifact read + re-derivation OFF the
    /// scheduler lock (`busy` is set for the duration). Cleared when the
    /// backend is installed (or the reload fails).
    loading: bool,
    /// Logical LRU timestamp (scheduler clock at the last submit).
    last_used: u64,
    /// Size of this adapter's artifact encoding, cached at registration
    /// and refreshed by checkpoint/spill (reporting: bytes-per-adapter).
    artifact_bytes: u64,
    /// Dense folded twin dispatched instead of `backend` while the slot
    /// serves merged (see the module docs' Merged serving section). The
    /// adapted `backend` stays the source of truth: spill/checkpoint
    /// always encode it, and drop the twin (fold determinism re-derives
    /// it bit-identically on re-promotion).
    merged_backend: Option<NativeBackend>,
    /// Merged-mode flag. Outlives the twin across spill/reload (the
    /// async reload lane re-promotes off-lock), so a spilled merged
    /// adapter comes back merged.
    merged: bool,
    stats: AdapterStats,
}

struct ServeState {
    slots: Vec<Slot>,
    /// Round-robin cursor (next slot index to consider).
    rr: usize,
    /// Total queued (not yet dispatched) jobs across slots.
    queued: usize,
    next_id: u64,
    /// Logical clock driving the LRU spill order.
    clock: u64,
    /// Worker panics contained so far (each retires the adapter whose
    /// compute panicked).
    worker_panics: u64,
    paused: bool,
    shutdown: bool,
    /// Dispatch-order trace of adapter ids (test instrumentation),
    /// truncated at `trace_cap` entries.
    trace: Vec<AdapterId>,
    trace_cap: usize,
    /// Weighted-fair tier weights (copied from [`ServeOptions`]); empty
    /// = pure round-robin.
    tier_weights: Vec<u64>,
    /// Tier currently holding the dispatch budget.
    tier_cursor: usize,
    /// Remaining dispatch units in the current tier's budget.
    tier_left: u64,
    /// Sticky flag: set the first time a deadline-carrying request is
    /// admitted, so deadline-free fleets never pay for the expiry sweep.
    has_deadlines: bool,
    /// Per-worker snapshot of its workspace K/V page pool's outstanding
    /// page count, published at every put-back and on the panic
    /// containment path (indexed by `WorkerCfg::index`). Sums to 0
    /// whenever no generation is in flight — the leak invariant
    /// [`ServeCore::pages_outstanding`] exposes and the panic tests pin.
    pages_outstanding: Vec<u64>,
}

struct Shared {
    state: Mutex<ServeState>,
    /// Workers wait here for runnable slots.
    work: Condvar,
    /// Evict/drain waiters wait here for put-backs.
    idle: Condvar,
}

/// Monotonic suffix so concurrent cores in one process get distinct
/// default spill directories.
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// Delete a spill file whose state has been safely reloaded. Never
/// silently swallowed: a failure cannot lose state (the in-memory copy is
/// already live) but leaves a stale artifact on disk, which the operator
/// should hear about.
fn remove_spill_file(path: &Path, ctx: &str) {
    if let Err(e) = std::fs::remove_file(path) {
        crate::warn_log!("{ctx}: could not remove spill file {}: {e}", path.display());
    }
}

/// The multi-adapter serving core. See the module docs for the design.
pub struct ServeCore {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    opts: ServeOptions,
    backbone: Arc<Backbone>,
    /// Resolved directory spilled artifacts are written to.
    spill_dir: PathBuf,
}

impl ServeCore {
    /// Spin up the worker pool over a shared frozen backbone.
    pub fn new(backbone: Arc<Backbone>, opts: ServeOptions) -> ServeCore {
        let spill_dir = opts.spill_dir.clone().unwrap_or_else(|| {
            std::env::temp_dir().join(format!(
                "psoft_spill_{}_{}",
                std::process::id(),
                SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
            ))
        });
        let shared = Arc::new(Shared {
            state: Mutex::new(ServeState {
                slots: Vec::new(),
                rr: 0,
                queued: 0,
                next_id: 0,
                clock: 0,
                worker_panics: 0,
                paused: opts.start_paused,
                shutdown: false,
                trace: Vec::with_capacity(opts.trace_cap),
                trace_cap: opts.trace_cap,
                tier_weights: opts.tier_weights.clone(),
                tier_cursor: 0,
                tier_left: opts.tier_weights.first().copied().unwrap_or(1).max(1),
                has_deadlines: false,
                pages_outstanding: vec![0; opts.workers.max(1)],
            }),
            work: Condvar::new(),
            idle: Condvar::new(),
        });
        let workers = (0..opts.workers.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                let cfg = WorkerCfg {
                    index: i,
                    burst: opts.burst.max(1),
                    decode_batch: opts.decode_batch.max(1),
                    coalesce_eval: opts.coalesce_eval,
                    prefill_chunk: opts.prefill_chunk.max(1),
                    backbone: Arc::clone(&backbone),
                    spill_dir: spill_dir.clone(),
                    max_resident: opts.max_resident,
                };
                thread::Builder::new()
                    .name(format!("psoft-serve-{i}"))
                    .spawn(move || worker_loop(&shared, cfg))
                    .expect("spawn serve worker")
            })
            .collect();
        ServeCore { shared, workers, opts, backbone, spill_dir }
    }

    /// The shared frozen backbone.
    pub fn backbone(&self) -> &Arc<Backbone> {
        &self.backbone
    }

    /// Build and register a fresh adapter on the shared backbone. The
    /// construction (SVD init etc.) runs on the caller's thread; serving
    /// of already-registered adapters continues meanwhile. The seed is
    /// recorded on the backend so spill/checkpoint artifacts can re-derive
    /// the frozen adapter tensors exactly.
    pub fn register(&self, label: &str, peft: &PeftConfig, seed: u64) -> AdapterId {
        self.register_backend(label, NativeBackend::for_adapter(&self.backbone, peft, seed))
    }

    /// Register an externally built backend (e.g. a previously evicted,
    /// fine-tuned adapter being re-installed, or one restored from an
    /// artifact). Never touches the backbone. Past the resident budget,
    /// the least-recently-used idle adapter spills to disk. Backends
    /// without a recorded construction seed (or in pretraining mode) are
    /// accepted but never spilled — their frozen tensors could not be
    /// reconstructed on reload.
    pub fn register_backend(&self, label: &str, backend: NativeBackend) -> AdapterId {
        // Arithmetic size of the artifact encoding (no serialization) —
        // reporting reads this cached value instead of re-encoding live
        // state; 0 for non-exportable backends.
        let artifact_bytes = if backend.artifact_exportable() {
            backend.artifact_encoded_len(label) as u64
        } else {
            0
        };
        // merge_resident fleets serve dense twins from the first dispatch.
        // The fold runs here, before the scheduler lock is taken, so
        // registering one adapter never stalls dispatch for the rest of
        // the fleet. A failed fold degrades to the adapted path (warned,
        // not fatal): the adapter still serves correctly, just slower.
        let (merged_backend, merged) = if self.opts.merge_resident {
            match backend.merged_twin() {
                Ok(twin) => (Some(twin), true),
                Err(e) => {
                    crate::warn_log!(
                        "register {label}: merge into backbone failed ({e}); \
                         serving the adapted path instead"
                    );
                    (None, false)
                }
            }
        } else {
            (None, false)
        };
        let mut st = relock(&self.shared.state);
        let id = AdapterId(st.next_id);
        st.next_id += 1;
        st.clock += 1;
        let slot = Slot {
            id,
            label: label.to_string(),
            backend: Some(backend),
            // decode_batch slots of headroom: an in-flight generation
            // GROUP re-enqueues its unfinished lanes at the queue front
            // after its dispatch quota, transiently holding up to
            // decode_batch slots beyond the submit-visible cap — the
            // pre-sizing guarantees a grouped re-enqueue can never hit a
            // (reallocating) full queue it created itself.
            queue: VecDeque::with_capacity(
                self.opts.queue_cap.max(1) + self.opts.decode_batch.max(1),
            ),
            busy: false,
            live: true,
            gens_inflight: 0,
            draining: false,
            spill: None,
            loading: false,
            last_used: st.clock,
            artifact_bytes,
            merged_backend,
            merged,
            stats: AdapterStats { merged, ..AdapterStats::default() },
        };
        // Reuse a fully-retired slot (evicted: state taken, not busy) so
        // the table doesn't grow without bound under churn.
        let idx = match st
            .slots
            .iter()
            .position(|s| !s.live && !s.busy && s.backend.is_none() && s.spill.is_none())
        {
            Some(i) => {
                st.slots[i] = slot;
                i
            }
            None => {
                st.slots.push(slot);
                st.slots.len() - 1
            }
        };
        self.spill_down_to(&mut st, self.opts.max_resident, Some(idx));
        drop(st);
        self.shared.work.notify_all();
        id
    }

    /// Strict eviction: remove an idle adapter, wait out its in-flight
    /// burst, and return the owned per-adapter state. Refuses with
    /// [`ServeError::PendingRequests`] (carrying the queued count) when
    /// requests are still queued — callers must pick a policy via
    /// [`ServeCore::evict_with`]. The backbone is untouched.
    pub fn evict(&self, id: AdapterId) -> Result<NativeBackend, ServeError> {
        self.evict_impl(id, true, false).map(|(backend, _)| backend)
    }

    /// Evict with an explicit policy for queued requests; returns the
    /// owned state and how many pending requests were failed (always 0
    /// for [`EvictMode::Drain`]).
    pub fn evict_with(
        &self,
        id: AdapterId,
        mode: EvictMode,
    ) -> Result<(NativeBackend, usize), ServeError> {
        match mode {
            EvictMode::Reject => self.evict_impl(id, false, false),
            EvictMode::Drain => self.evict_impl(id, false, true),
        }
    }

    fn evict_impl(
        &self,
        id: AdapterId,
        strict: bool,
        drain: bool,
    ) -> Result<(NativeBackend, usize), ServeError> {
        let mut st = relock(&self.shared.state);
        let idx = st
            .slots
            .iter()
            .position(|s| s.live && s.id == id)
            .ok_or(ServeError::UnknownAdapter)?;
        if st.slots[idx].draining {
            // Another evict_with(Drain) owns this slot already.
            return Err(ServeError::Evicted);
        }
        // Strict eviction refuses pending work: queued requests, plus
        // every lane of an in-flight generation *group* — unlike a
        // one-shot burst, they cannot be waited out (they would
        // re-enqueue), only failed or drained.
        if strict && (!st.slots[idx].queue.is_empty() || st.slots[idx].gens_inflight > 0) {
            let pending = st.slots[idx].queue.len() + st.slots[idx].gens_inflight;
            return Err(ServeError::PendingRequests(pending));
        }
        if drain {
            // Refuse new submissions, let dispatch serve the queue out.
            st.slots[idx].draining = true;
            if st.paused {
                st.paused = false;
                self.shared.work.notify_all();
            }
            while st.slots[idx].live
                && st.slots[idx].id == id
                && (!st.slots[idx].queue.is_empty() || st.slots[idx].busy)
            {
                st = rewait(&self.shared.idle, st);
            }
            if !st.slots[idx].live || st.slots[idx].id != id {
                // A concurrent evict retired the slot while we drained.
                return Err(ServeError::Evicted);
            }
        }
        st.slots[idx].live = false;
        st.slots[idx].draining = false;
        st.slots[idx].loading = false;
        // Unqueue the not-yet-started jobs; their tickets are failed only
        // after the scheduler lock is released (ticket locks are never
        // taken under the state lock — see the worker's completion path).
        let mut failed: Vec<Job> = Vec::with_capacity(st.slots[idx].queue.len());
        while let Some(job) = st.slots[idx].queue.pop_front() {
            st.queued -= 1;
            failed.push(job);
        }
        while st.slots[idx].busy {
            st = rewait(&self.shared.idle, st);
        }
        // The merged twin is derived state — the caller gets the adapted
        // backend; a re-registration can re-promote.
        st.slots[idx].merged_backend = None;
        st.slots[idx].merged = false;
        let backend = match st.slots[idx].backend.take() {
            Some(b) => b,
            None => {
                let Some(path) = st.slots[idx].spill.take() else {
                    // Neither resident nor spilled: the worker running
                    // this adapter panicked while we waited out its burst
                    // (the panic path retires the slot and drops the
                    // possibly-corrupt state). Surface the typed error —
                    // panicking here would re-create the cascade the
                    // containment exists to stop. The jobs we unqueued
                    // above still get failed below.
                    drop(st);
                    for job in failed {
                        fail(&job.ticket, ServeError::Evicted);
                    }
                    return Err(ServeError::WorkerPanicked);
                };
                // State is on disk: evicting a spilled adapter hands back
                // its reloaded (exact) state.
                match self.load_artifact(&path) {
                    Ok(b) => {
                        remove_spill_file(&path, "evict");
                        b
                    }
                    Err(e) => {
                        crate::warn_log!(
                            "evict {id}: reload from {} failed: {e:#}",
                            path.display()
                        );
                        // Restore the slot (spill file kept, adapter back
                        // to live+spilled) so a transient I/O failure is
                        // retryable instead of stranding the state. We
                        // held the lock continuously since live=false, so
                        // nothing observed the intermediate state. A
                        // Loading slot may have had queued jobs — fail
                        // them (outside the lock) rather than restoring a
                        // queue the caller believed empty.
                        st.slots[idx].spill = Some(path);
                        st.slots[idx].live = true;
                        drop(st);
                        for job in failed {
                            fail(&job.ticket, ServeError::Evicted);
                        }
                        return Err(ServeError::ArtifactFailed);
                    }
                }
            }
        };
        drop(st);
        let n_failed = failed.len();
        for job in failed {
            fail(&job.ticket, ServeError::Evicted);
        }
        Ok((backend, n_failed))
    }

    /// Snapshot one live adapter to `path` as a versioned artifact without
    /// evicting it (its queue is untouched; an in-flight burst is waited
    /// out first). Returns the bytes written.
    pub fn checkpoint(&self, id: AdapterId, path: &Path) -> anyhow::Result<u64> {
        let mut st = relock(&self.shared.state);
        let idx = st
            .slots
            .iter()
            .position(|s| s.live && s.id == id)
            .ok_or_else(|| anyhow::anyhow!("checkpoint: no live adapter {id}"))?;
        loop {
            if let Some(spill) = st.slots[idx].spill.clone() {
                // Already on disk in artifact form — copy verbatim. The
                // copy runs under the scheduler lock so a concurrent
                // submit's reload (which deletes the spill file) cannot
                // race it; spill files are artifact-sized (small).
                if let Some(parent) = path.parent() {
                    if !parent.as_os_str().is_empty() {
                        std::fs::create_dir_all(parent)?;
                    }
                }
                let bytes = std::fs::copy(&spill, path)?;
                return Ok(bytes);
            }
            if !st.slots[idx].busy {
                break;
            }
            st = rewait(&self.shared.idle, st);
            if !st.slots[idx].live || st.slots[idx].id != id {
                anyhow::bail!("adapter {id} was evicted during checkpoint");
            }
        }
        // Borrow the state exclusively (marked busy so dispatch and evict
        // wait), serialize outside the scheduler lock, put it back.
        let backend = st.slots[idx].backend.take().expect("idle live slot holds its backend");
        st.slots[idx].busy = true;
        let label = st.slots[idx].label.clone();
        drop(st);
        let result =
            backend.to_artifact(&label, &self.backbone).and_then(|art| art.write_to(path));
        let mut st = relock(&self.shared.state);
        st.slots[idx].backend = Some(backend);
        st.slots[idx].busy = false;
        if let Ok(bytes) = &result {
            st.slots[idx].artifact_bytes = *bytes;
        }
        drop(st);
        self.shared.work.notify_all();
        self.shared.idle.notify_all();
        result
    }

    /// Promote one live adapter to **merged mode**: fold its adapted
    /// weights into a dense twin ([`NativeBackend::merged_twin`]) and
    /// serve eval/generate dispatches from the twin until
    /// [`ServeCore::demote`]. The fold runs **off the scheduler lock**
    /// (the slot is borrowed busy, like `checkpoint`), so promoting one
    /// adapter never stalls the fleet. Idempotent; refuses while the
    /// adapter is spilled (submit once to trigger the transparent
    /// reload, or raise the resident budget). While merged, train
    /// submits are refused with [`ServeError::MergedAdapter`].
    pub fn promote(&self, id: AdapterId) -> anyhow::Result<()> {
        let mut st = relock(&self.shared.state);
        let idx = st
            .slots
            .iter()
            .position(|s| s.live && s.id == id)
            .ok_or_else(|| anyhow::anyhow!("promote: no live adapter {id}"))?;
        if st.slots[idx].merged && st.slots[idx].merged_backend.is_some() {
            return Ok(());
        }
        loop {
            if st.slots[idx].spill.is_some() || st.slots[idx].loading {
                anyhow::bail!(
                    "promote: adapter {id} is spilled to disk; submit once to reload it first"
                );
            }
            if !st.slots[idx].busy {
                break;
            }
            st = rewait(&self.shared.idle, st);
            if !st.slots[idx].live || st.slots[idx].id != id {
                anyhow::bail!("adapter {id} was evicted during promote");
            }
        }
        // Borrow the state exclusively (busy, so dispatch and evict
        // wait), fold outside the scheduler lock, put both back.
        let backend = st.slots[idx].backend.take().expect("idle live slot holds its backend");
        st.slots[idx].busy = true;
        drop(st);
        let folded = backend.merged_twin();
        let mut st = relock(&self.shared.state);
        st.slots[idx].backend = Some(backend);
        st.slots[idx].busy = false;
        let result = match folded {
            Ok(twin) => {
                st.slots[idx].merged_backend = Some(twin);
                st.slots[idx].merged = true;
                st.slots[idx].stats.merged = true;
                Ok(())
            }
            // A failed fold leaves the slot exactly as it was: adapted,
            // trainable, serving.
            Err(e) => Err(e),
        };
        drop(st);
        self.shared.work.notify_all();
        self.shared.idle.notify_all();
        result
    }

    /// Leave merged mode: drop the dense twin and dispatch the adapted
    /// path again (train submits accepted once more). Waits out an
    /// in-flight burst so a dispatched merged group completes on the
    /// twin it started with. Idempotent.
    pub fn demote(&self, id: AdapterId) -> anyhow::Result<()> {
        let mut st = relock(&self.shared.state);
        let idx = st
            .slots
            .iter()
            .position(|s| s.live && s.id == id)
            .ok_or_else(|| anyhow::anyhow!("demote: no live adapter {id}"))?;
        while st.slots[idx].busy {
            st = rewait(&self.shared.idle, st);
            if !st.slots[idx].live || st.slots[idx].id != id {
                anyhow::bail!("adapter {id} was evicted during demote");
            }
        }
        st.slots[idx].merged_backend = None;
        st.slots[idx].merged = false;
        st.slots[idx].stats.merged = false;
        Ok(())
    }

    /// Whether the adapter currently serves in merged mode (`None` for
    /// unknown/evicted ids). True for a spilled merged adapter too —
    /// the reload lane re-promotes it on the way back.
    pub fn is_merged(&self, id: AdapterId) -> Option<bool> {
        let st = relock(&self.shared.state);
        st.slots.iter().find(|s| s.live && s.id == id).map(|s| s.merged)
    }

    /// Σ K/V-cache pages currently checked out across all worker
    /// workspaces (each worker publishes its pool's outstanding count at
    /// put-back and on the panic containment path). Returns to 0
    /// whenever no generation is in flight — the no-leak invariant the
    /// worker-panic tests pin.
    pub fn pages_outstanding(&self) -> u64 {
        relock(&self.shared.state).pages_outstanding.iter().sum()
    }

    /// Register an adapter from an artifact file exported by
    /// [`ServeCore::checkpoint`] / `psoft export` — validated against this
    /// core's backbone fingerprint before anything is installed.
    pub fn restore(&self, label: &str, path: &Path) -> anyhow::Result<AdapterId> {
        let backend = self.load_artifact(path)?;
        Ok(self.register_backend(label, backend))
    }

    /// Read + validate + reconstruct an artifact on this core's backbone.
    fn load_artifact(&self, path: &Path) -> anyhow::Result<NativeBackend> {
        let art = AdapterArtifact::read_from(path)?;
        Ok(NativeBackend::from_artifact(&self.backbone, &art)?)
    }

    /// Spill the least-recently-used idle adapters until at most `budget`
    /// are resident. Best-effort: adapters that are busy, draining, or
    /// have queued work are never spilled, so the count can transiently
    /// stay above budget. No-op when `max_resident` is 0 (unlimited).
    fn spill_down_to(
        &self,
        st: &mut MutexGuard<'_, ServeState>,
        budget: usize,
        exempt: Option<usize>,
    ) {
        if self.opts.max_resident == 0 {
            return;
        }
        loop {
            let resident = st
                .slots
                .iter()
                .filter(|s| s.live && (s.backend.is_some() || (s.busy && !s.loading)))
                .count();
            if resident <= budget {
                return;
            }
            let victim = st
                .slots
                .iter()
                .enumerate()
                .filter(|(i, s)| {
                    Some(*i) != exempt
                        && s.live
                        && !s.busy
                        && !s.draining
                        && s.queue.is_empty()
                        && s.backend.as_ref().map_or(false, |b| b.artifact_exportable())
                })
                .min_by_key(|(_, s)| s.last_used)
                .map(|(i, _)| i);
            let Some(v) = victim else { return };
            if let Err(e) = self.spill_slot(st, v) {
                crate::warn_log!(
                    "resident budget: spilling {} failed ({e:#}); keeping it in memory",
                    st.slots[v].id
                );
                return;
            }
        }
    }

    /// Serialize one idle slot's state to the spill directory and drop the
    /// in-memory copy.
    fn spill_slot(
        &self,
        st: &mut MutexGuard<'_, ServeState>,
        idx: usize,
    ) -> anyhow::Result<()> {
        let backend = st.slots[idx].backend.take().expect("spill victim is resident");
        let label = st.slots[idx].label.clone();
        let path = self.spill_dir.join(format!("adapter_{}.psoftad", st.slots[idx].id.0));
        let written = backend
            .to_artifact(&label, &self.backbone)
            .and_then(|art| art.write_to(&path));
        match written {
            Ok(bytes) => {
                st.slots[idx].spill = Some(path);
                st.slots[idx].artifact_bytes = bytes;
                // The merged twin is derived state — never spilled. The
                // `merged` flag survives; the reload lane re-promotes
                // (bit-identically, by fold determinism) on the way back.
                st.slots[idx].merged_backend = None;
                Ok(())
            }
            Err(e) => {
                // Keep the adapter resident rather than losing state.
                st.slots[idx].backend = Some(backend);
                Err(e)
            }
        }
    }

    /// Enqueue one request for `id` — the single typed entry point for
    /// eval, train, and generation work — re-arming `ticket` to receive
    /// the result. Returns an [`Admission`] outcome; the ticket is
    /// re-armed only on [`Admission::Admitted`] — a rejected or shed
    /// submit leaves the ticket's previous completion intact.
    ///
    /// Zero-allocation on the warm resident path: batches and prompts
    /// travel as `Arc` clones, `SubmitOptions`/`Admission` are `Copy`,
    /// and the queue is pre-sized. A submit against a **spilled**
    /// adapter marks the slot Loading and enqueues — a worker reloads
    /// the artifact on the async reload lane, off the scheduler lock, so
    /// callers never observe eviction-to-disk except as latency and a
    /// cold adapter never stalls fleet dispatch.
    ///
    /// `opts` carries per-request scheduling state: a tier for the
    /// weighted-fair scheduler and/or a relative completion deadline —
    /// see the module docs' Scheduling & admission section for the shed
    /// semantics.
    ///
    /// Generation requests are validated against the shared backbone
    /// before anything is enqueued: decoder architecture and a non-empty
    /// in-vocab prompt — violations return
    /// `Admission::Rejected(ServeError::InvalidRequest)` — and
    /// `prompt.len() + max_new_tokens ≤ max_seq` (the KV-cache budget),
    /// whose violation returns the typed
    /// [`ServeError::DecodeOverflow`] carrying the numbers a client
    /// needs to retry within the window. Train submits against an
    /// adapter serving in merged mode are refused with
    /// [`ServeError::MergedAdapter`] (see the module docs' Merged
    /// serving section).
    pub fn submit(
        &self,
        id: AdapterId,
        req: Request,
        ticket: &Ticket,
        opts: SubmitOptions,
    ) -> Admission {
        let kind = match req {
            Request::Eval { batch } => JobKind::Batch { batch, req: ReqKind::Eval },
            Request::Train { batch, hyper } => {
                JobKind::Batch { batch, req: ReqKind::Train(hyper) }
            }
            Request::Generate { prompt, max_new_tokens, greedy } => {
                let cfg = &self.backbone.cfg;
                if !self.backbone.supports_decode()
                    || prompt.is_empty()
                    || prompt.iter().any(|&t| t < 0 || t as usize >= cfg.vocab_size)
                {
                    return Admission::Rejected(ServeError::InvalidRequest);
                }
                if prompt.len() + max_new_tokens > cfg.max_seq {
                    // Typed overflow with the retry-relevant numbers —
                    // distinct from the shape/vocab rejections above so a
                    // client can clamp max_new and resubmit.
                    return Admission::Rejected(ServeError::DecodeOverflow {
                        prompt: prompt.len(),
                        max_new: max_new_tokens,
                        max_seq: cfg.max_seq,
                    });
                }
                let stream = native::DecodeStream::new(&prompt);
                JobKind::Gen(GenJob {
                    prompt,
                    max_new_tokens,
                    greedy,
                    stream,
                    lane: None,
                    emitted: 0,
                })
            }
        };
        let now = Instant::now();
        let mut st = relock(&self.shared.state);
        if st.shutdown {
            return Admission::Rejected(ServeError::ShuttingDown);
        }
        let cap = self.opts.queue_cap.max(1);
        let Some(idx) = st.slots.iter().position(|s| s.live && s.id == id) else {
            return Admission::Rejected(ServeError::UnknownAdapter);
        };
        if st.slots[idx].draining {
            // Evict-with-drain in progress: refuses new work with the
            // remaining drain count.
            return Admission::Rejected(ServeError::Draining {
                queued: st.slots[idx].queue.len(),
            });
        }
        // Merged mode serves inference only: a train step needs the
        // adapted parameterization the fold erased from the dispatch
        // twin. Refuse typed; `demote` restores trainability.
        if st.slots[idx].merged
            && matches!(kind, JobKind::Batch { req: ReqKind::Train(_), .. })
        {
            return Admission::Rejected(ServeError::MergedAdapter);
        }
        // A zero (or elapsed-at-submit) deadline can never be met: shed
        // typed instead of queueing doomed work.
        if opts.deadline.map_or(false, |d| d.is_zero()) {
            st.slots[idx].stats.shed += 1;
            return Admission::Shed(ShedReason::DeadlineExpired);
        }
        if st.slots[idx].queue.len() >= cap {
            st.slots[idx].stats.rejected += 1;
            return Admission::Rejected(ServeError::QueueFull {
                depth: st.slots[idx].queue.len(),
                cap,
            });
        }
        // Queue-delay admission shedding: if the queue front has already
        // waited past the bound, the adapter is behind its SLO — turn
        // new work away now rather than queueing a future deadline miss.
        if self.opts.shed_after_ms > 0 {
            let bound = Duration::from_millis(self.opts.shed_after_ms);
            let delayed = st.slots[idx]
                .queue
                .front()
                .map_or(false, |j| now.duration_since(j.enqueued) > bound);
            if delayed {
                st.slots[idx].stats.shed += 1;
                return Admission::Shed(ShedReason::QueueDelay);
            }
        }
        st.clock += 1;
        st.slots[idx].last_used = st.clock;
        if st.slots[idx].spill.is_some() {
            // Async reload lane: mark Loading and fall through to the
            // enqueue — a worker runs the artifact read + re-derivation
            // off the scheduler lock (see `run_reload`).
            st.slots[idx].loading = true;
        } else if self.opts.max_resident != 0 {
            // Already resident: opportunistically re-enforce the budget so
            // adapters left resident by an earlier concurrent burst (no
            // idle victims at the time) spill once they quiesce. With the
            // default unlimited budget this branch is a no-op, keeping the
            // warm resident path allocation-free.
            self.spill_down_to(&mut st, self.opts.max_resident, Some(idx));
        }
        let deadline = opts.deadline.map(|d| now + d);
        if deadline.is_some() {
            st.has_deadlines = true;
        }
        // Arm under the state lock: workers need that lock to dispatch,
        // so the job cannot complete before it is armed. (No path ever
        // holds a ticket lock and then takes the state lock, so this
        // nesting is deadlock-free.)
        ticket.arm();
        st.slots[idx].queue.push_back(Job {
            kind,
            ticket: Arc::clone(&ticket.inner),
            enqueued: now,
            tier: opts.priority,
            deadline,
        });
        st.queued += 1;
        drop(st);
        self.shared.work.notify_one();
        Admission::Admitted
    }

    /// Block until every queued and in-flight request has completed.
    /// (Unpauses dispatch if the core started paused.)
    pub fn drain(&self) {
        let mut st = relock(&self.shared.state);
        if st.paused {
            st.paused = false;
            self.shared.work.notify_all();
        }
        while st.queued > 0 || st.slots.iter().any(|s| s.busy) {
            st = rewait(&self.shared.idle, st);
        }
    }

    /// Start dispatching (cores built with `start_paused`).
    pub fn resume(&self) {
        let mut st = relock(&self.shared.state);
        st.paused = false;
        drop(st);
        self.shared.work.notify_all();
    }

    /// Stats for one adapter (live or already evicted, while its slot has
    /// not been reused).
    pub fn stats(&self, id: AdapterId) -> Option<AdapterStats> {
        let st = relock(&self.shared.state);
        st.slots.iter().find(|s| s.id == id).map(|s| s.stats)
    }

    /// (id, label, stats) of every live adapter, in slot order.
    pub fn adapters(&self) -> Vec<(AdapterId, String, AdapterStats)> {
        let st = relock(&self.shared.state);
        st.slots
            .iter()
            .filter(|s| s.live)
            .map(|s| (s.id, s.label.clone(), s.stats))
            .collect()
    }

    /// Number of live adapters.
    pub fn num_adapters(&self) -> usize {
        relock(&self.shared.state).slots.iter().filter(|s| s.live).count()
    }

    /// Workers whose compute has panicked (each panic retires the adapter
    /// it was running; the worker itself recovers and keeps serving).
    pub fn worker_panics(&self) -> u64 {
        relock(&self.shared.state).worker_panics
    }

    /// Currently queued (undispatched) requests for one adapter.
    pub fn queue_len(&self, id: AdapterId) -> Option<usize> {
        let st = relock(&self.shared.state);
        st.slots.iter().find(|s| s.live && s.id == id).map(|s| s.queue.len())
    }

    /// Size of this adapter's artifact encoding in bytes (cached at
    /// registration, refreshed by checkpoint/spill) — the bytes-per-
    /// adapter figure reports put next to Table 8 parameter counts.
    pub fn artifact_bytes(&self, id: AdapterId) -> Option<u64> {
        let st = relock(&self.shared.state);
        st.slots.iter().find(|s| s.live && s.id == id).map(|s| s.artifact_bytes)
    }

    /// Whether the adapter's state is currently in memory (`false` ⇒
    /// spilled to disk, possibly mid-reload on the async reload lane).
    pub fn resident(&self, id: AdapterId) -> Option<bool> {
        let st = relock(&self.shared.state);
        st.slots
            .iter()
            .find(|s| s.live && s.id == id)
            .map(|s| s.backend.is_some() || (s.busy && !s.loading))
    }

    /// Number of adapters whose state is resident in memory.
    pub fn num_resident(&self) -> usize {
        let st = relock(&self.shared.state);
        st.slots
            .iter()
            .filter(|s| s.live && (s.backend.is_some() || (s.busy && !s.loading)))
            .count()
    }

    /// The directory spilled artifacts are written to.
    pub fn spill_dir(&self) -> &Path {
        &self.spill_dir
    }

    /// The recorded dispatch order (adapter id per dispatched request),
    /// up to `trace_cap` entries.
    pub fn trace(&self) -> Vec<AdapterId> {
        relock(&self.shared.state).trace.clone()
    }
}

impl Drop for ServeCore {
    fn drop(&mut self) {
        {
            let mut st = relock(&self.shared.state);
            st.shutdown = true;
            st.paused = false;
        }
        self.shared.work.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Spilled artifacts are a transparent cache, not the durability
        // API (that is `checkpoint`): remove the files this core owns,
        // then the spill directory if that leaves it empty. A caller-
        // provided directory with other contents is left in place.
        let st = relock(&self.shared.state);
        for s in &st.slots {
            if let Some(p) = &s.spill {
                remove_spill_file(p, "shutdown");
            }
        }
        drop(st);
        let _ = std::fs::remove_dir(&self.spill_dir);
    }
}

/// Round-robin scan for a runnable slot, optionally restricted to one
/// tier (a dispatch unit's tier is its queue-front job's tier, clamped
/// to the configured tier count). Loading slots are never runnable —
/// their backend is absent by construction.
fn rr_scan(st: &ServeState, tier: Option<usize>) -> Option<usize> {
    let n = st.slots.len();
    let nt = st.tier_weights.len();
    for k in 0..n {
        let i = (st.rr + k) % n;
        let s = &st.slots[i];
        if !(s.live && !s.busy && s.backend.is_some() && !s.queue.is_empty()) {
            continue;
        }
        if let Some(t) = tier {
            let front_tier = s.queue.front().map_or(0, |j| j.tier.min(nt.saturating_sub(1)));
            if front_tier != t {
                continue;
            }
        }
        return Some(i);
    }
    None
}

/// Pick the next slot to dispatch. With the default empty
/// `tier_weights` this IS the pre-tier pure round-robin scan —
/// bit-identical dispatch traces, no budget bookkeeping touched. With N
/// weights, tier `tier_cursor` spends its budget (`tier_left` dispatch
/// units) first; a tier with no runnable work forfeits the remainder
/// (work-conserving), and budget is only consumed on real dispatches.
fn next_runnable(st: &mut ServeState) -> Option<usize> {
    if st.tier_weights.is_empty() {
        return rr_scan(st, None);
    }
    let nt = st.tier_weights.len();
    for k in 0..nt {
        let t = (st.tier_cursor + k) % nt;
        if let Some(i) = rr_scan(st, Some(t)) {
            if k > 0 {
                // Intervening tiers had nothing runnable: their budget
                // is forfeit, tier t starts a fresh one.
                st.tier_cursor = t;
                st.tier_left = st.tier_weights[t].max(1);
            }
            st.tier_left -= 1;
            if st.tier_left == 0 {
                st.tier_cursor = (st.tier_cursor + 1) % nt;
                st.tier_left = st.tier_weights[st.tier_cursor].max(1);
            }
            return Some(i);
        }
    }
    None
}

/// Pick a Loading slot awaiting its async reload (idle, state on disk).
fn next_reload(st: &ServeState) -> Option<usize> {
    st.slots
        .iter()
        .position(|s| s.live && !s.busy && s.loading && s.backend.is_none() && s.spill.is_some())
}

/// Deadline sweep: shed every queued job whose deadline has passed,
/// failing its ticket typed ([`ServeError::Shed`]) — never a silent
/// drop. Runs before every dispatch decision, but only once a
/// deadline-carrying request has ever been admitted
/// (`ServeState::has_deadlines`), so deadline-free fleets pay nothing.
/// Jobs already on a worker run to completion. Ticket locks nest under
/// the state lock (same order as `submit`'s arm), so failing under the
/// sweep is deadlock-free.
fn shed_expired(st: &mut ServeState, now: Instant) {
    let mut shed_total = 0usize;
    for i in 0..st.slots.len() {
        let slot = &mut st.slots[i];
        if !slot.live {
            continue;
        }
        // Only the queue front is ever dispatched next, but an expired
        // job can sit behind a live one — scan the whole queue so a
        // deep expired job sheds now, not after everything ahead of it.
        let mut j = 0;
        while j < slot.queue.len() {
            let expired =
                slot.queue[j].deadline.map_or(false, |d| now >= d);
            if expired {
                let job = slot.queue.remove(j).unwrap();
                slot.stats.shed += 1;
                shed_total += 1;
                fail(&job.ticket, ServeError::Shed(ShedReason::DeadlineExpired));
            } else {
                j += 1;
            }
        }
    }
    st.queued -= shed_total;
}

/// What one dispatch unit holds (see the module docs' Continuous
/// batching section): the maximal same-kind run at the queue front.
#[derive(Clone, Copy, PartialEq, Eq)]
enum DispatchMode {
    /// Up to `burst` one-shot eval/train requests, serviced one by one.
    Oneshot,
    /// Up to `decode_batch` generations advanced in lockstep as a group.
    GenGroup,
    /// ≥ 2 shape-compatible eval requests merged into one forward.
    EvalGroup,
}

fn job_is_gen(j: &Job) -> bool {
    matches!(j.kind, JobKind::Gen(_))
}

/// The batch of an `Eval` job (None for train/generation jobs).
fn eval_batch_of(j: &Job) -> Option<&Arc<Batch>> {
    match &j.kind {
        JobKind::Batch { batch, req: ReqKind::Eval } => Some(batch),
        _ => None,
    }
}

/// Does this queued job coalesce with an eval group of the given head
/// shape (same seq length, same target kind)? Empty batches never
/// coalesce — they would make a degenerate span (and panic the span
/// scatter) where the uncoalesced path serves them without incident.
fn coalesces_with(j: &Job, seq0: usize, disc0: std::mem::Discriminant<Target>) -> bool {
    eval_batch_of(j)
        .map(|b| b.batch > 0 && b.seq == seq0 && std::mem::discriminant(&b.target) == disc0)
        .unwrap_or(false)
}

/// Per-worker configuration, cloned into each worker thread at core
/// construction. Carries the backbone and spill knobs the async reload
/// lane needs to run artifact I/O without a `ServeCore` reference.
struct WorkerCfg {
    /// This worker's index into `ServeState::pages_outstanding`.
    index: usize,
    burst: usize,
    decode_batch: usize,
    coalesce_eval: bool,
    /// Prompt tokens per prompt-phase lane per lockstep group step
    /// ([`ServeOptions::prefill_chunk`], pre-clamped ≥ 1).
    prefill_chunk: usize,
    backbone: Arc<Backbone>,
    spill_dir: PathBuf,
    max_resident: usize,
}

/// What one selection decided: run compute for a dispatched batch, or
/// run an async artifact reload for a Loading slot. (The size asymmetry
/// is fine — exactly one `Unit` exists per worker at a time.)
#[allow(clippy::large_enum_variant)]
enum Unit {
    /// The bool records which backend the dispatch borrowed: `true` =
    /// the slot's merged twin (put-back must restore `merged_backend`,
    /// and emitted tokens count as merged).
    Compute(NativeBackend, DispatchMode, bool),
    Reload(PathBuf),
}

/// Async reload lane: bring a Loading slot's state back from disk with
/// the scheduler lock released across the artifact read and frozen-
/// tensor re-derivation (the SVD), so every other adapter keeps
/// dispatching while this one warms up. The slot is `busy` for the
/// duration (dispatch, evict and checkpoint all wait on `busy`).
///
/// Room is made under the resident budget FIRST, also off-lock: the LRU
/// idle victim is marked busy under the lock, serialized outside it,
/// and published back. On reload failure the slot returns to spilled
/// (artifact kept — the next submit retries) and its queued requests
/// fail typed with [`ServeError::ArtifactFailed`].
fn run_reload(shared: &Shared, cfg: &WorkerCfg, idx: usize, path: PathBuf) {
    // Phase 1: spill LRU victims until the reload target fits the
    // budget (its own slot counts once resident, hence `- 1`).
    if cfg.max_resident != 0 {
        loop {
            let victim = {
                let mut st = relock(&shared.state);
                let resident = st
                    .slots
                    .iter()
                    .filter(|s| s.live && (s.backend.is_some() || (s.busy && !s.loading)))
                    .count();
                if resident < cfg.max_resident {
                    None
                } else {
                    let v = st
                        .slots
                        .iter()
                        .enumerate()
                        .filter(|(i, s)| {
                            *i != idx
                                && s.live
                                && !s.busy
                                && !s.draining
                                && s.queue.is_empty()
                                && s.backend.as_ref().map_or(false, |b| b.artifact_exportable())
                        })
                        .min_by_key(|(_, s)| s.last_used)
                        .map(|(i, _)| i);
                    match v {
                        Some(v) => {
                            let backend =
                                st.slots[v].backend.take().expect("spill victim is resident");
                            st.slots[v].busy = true;
                            let label = st.slots[v].label.clone();
                            let vpath = cfg
                                .spill_dir
                                .join(format!("adapter_{}.psoftad", st.slots[v].id.0));
                            Some((v, backend, label, vpath))
                        }
                        None => None,
                    }
                }
            };
            let Some((v, backend, label, vpath)) = victim else { break };
            let written =
                backend.to_artifact(&label, &cfg.backbone).and_then(|art| art.write_to(&vpath));
            let mut st = relock(&shared.state);
            match written {
                Ok(bytes) => {
                    st.slots[v].spill = Some(vpath);
                    st.slots[v].artifact_bytes = bytes;
                    // Derived state — dropped on spill, re-folded on
                    // reload (the `merged` flag survives).
                    st.slots[v].merged_backend = None;
                    st.slots[v].busy = false;
                }
                Err(e) => {
                    crate::warn_log!(
                        "reload lane: spilling {} failed ({e:#}); keeping it in memory",
                        st.slots[v].id
                    );
                    st.slots[v].backend = Some(backend);
                    st.slots[v].busy = false;
                    drop(st);
                    shared.work.notify_all();
                    shared.idle.notify_all();
                    // Best-effort budget: stop trying, reload over-budget.
                    break;
                }
            }
            drop(st);
            shared.work.notify_all();
            shared.idle.notify_all();
        }
    }
    // Phase 2: the reload itself — artifact read + validation + frozen
    // re-derivation, all off-lock. Panics are contained like compute
    // panics, but the adapter is NOT retired: its exact state is still
    // safe on disk, so the slot just returns to spilled.
    let loaded = catch_unwind(AssertUnwindSafe(|| {
        let art = AdapterArtifact::read_from(&path)?;
        anyhow::Ok(NativeBackend::from_artifact(&cfg.backbone, &art)?)
    }));
    match loaded {
        Ok(Ok(backend)) => {
            // A merged slot comes back merged: re-fold OFF the lock
            // before installing (fold determinism makes the re-derived
            // twin bit-identical to the one spill dropped). The flag is
            // stable while this lane holds `busy`, so the short locked
            // read then unlocked fold is race-free.
            let want_merged = relock(&shared.state).slots[idx].merged;
            let twin = if want_merged {
                match backend.merged_twin() {
                    Ok(t) => Some(t),
                    Err(e) => {
                        crate::warn_log!(
                            "async reload: re-merge failed ({e:#}); serving the adapted path"
                        );
                        None
                    }
                }
            } else {
                None
            };
            let mut st = relock(&shared.state);
            // Install unconditionally — if the slot was retired while we
            // loaded (concurrent evict waits on `busy` and will take the
            // backend; panic-retire of a Loading slot cannot happen, its
            // compute never ran), the waiter receives the state.
            st.slots[idx].backend = Some(backend);
            if want_merged {
                st.slots[idx].merged = twin.is_some();
                st.slots[idx].stats.merged = twin.is_some();
                st.slots[idx].merged_backend = twin;
            }
            st.slots[idx].spill = None;
            st.slots[idx].loading = false;
            st.slots[idx].busy = false;
            drop(st);
            remove_spill_file(&path, "async-reload");
        }
        Ok(Err(e)) => {
            crate::warn_log!("async reload from {} failed: {e:#}", path.display());
            fail_reload(shared, idx);
        }
        Err(_) => {
            crate::warn_log!("async reload from {} panicked", path.display());
            fail_reload(shared, idx);
        }
    }
    shared.work.notify_all();
    shared.idle.notify_all();
}

/// Failure tail of [`run_reload`]: back to spilled (retryable — the
/// artifact is kept on disk), queued requests fail typed.
fn fail_reload(shared: &Shared, idx: usize) {
    let mut st = relock(&shared.state);
    st.slots[idx].loading = false;
    st.slots[idx].busy = false;
    let mut failed: Vec<Job> = Vec::with_capacity(st.slots[idx].queue.len());
    while let Some(job) = st.slots[idx].queue.pop_front() {
        st.queued -= 1;
        failed.push(job);
    }
    drop(st);
    for job in failed {
        fail(&job.ticket, ServeError::ArtifactFailed);
    }
}

fn worker_loop(shared: &Shared, cfg: WorkerCfg) {
    let (burst, decode_batch, coalesce_eval) = (cfg.burst, cfg.decode_batch, cfg.coalesce_eval);
    let mut ws = Workspace::new();
    let mut jobs: Vec<Job> = Vec::with_capacity(burst.max(decode_batch));
    // Warm per-lane K/V rings: attached to a generation on its first
    // dispatch, returned here when it completes (buffers stay
    // workspace-warm, so back-to-back generations allocate nothing).
    let mut lane_pool: Vec<DecodeLane> = Vec::new();
    // Lockstep group state: lanes join for one burst, leave after it.
    let mut gc = GroupDecodeCache::new();
    gc.set_prefill_chunk(cfg.prefill_chunk);
    // Per-lane tokens emitted by the current group burst (streamed to
    // each lane's ticket after the burst; pre-sized for decode_batch
    // lanes × burst steps, never reallocates once warm).
    let mut fresh: Vec<Vec<i32>> =
        (0..decode_batch).map(|_| Vec::with_capacity(burst)).collect();
    // Unfinished generations to push back to the queue front as a block.
    let mut requeue: Vec<Job> = Vec::with_capacity(decode_batch);
    // TTFT samples (ns, tier) gathered during the current dispatch,
    // recorded into the slot's combined and tier-split sketches at
    // publish time. Pre-sized for the largest dispatch unit, so warm
    // dispatches never allocate.
    let mut ttft_samples: Vec<(u64, usize)> = Vec::with_capacity(burst.max(decode_batch));
    // Coalesced-eval scratch: the merged batch (vectors reused across
    // dispatches) and the per-request example counts.
    let mut merged = Batch {
        batch: 0,
        seq: 0,
        tokens: Vec::new(),
        pad: Vec::new(),
        target: Target::Class(Vec::new()),
    };
    let mut spans: Vec<usize> = Vec::with_capacity(decode_batch);
    loop {
        // Dispatch: shed expired deadlines, prefer a pending async
        // reload, then pick the next runnable slot (round-robin, or
        // weighted-fair over tiers) and form a batch from the queue
        // front — a generation GROUP (≤ decode_batch lanes, advanced ≤
        // `burst` lockstep steps, re-enqueued at the front if
        // unfinished), a coalesced eval group, or a one-shot burst. One
        // dispatch consumes one burst quota whatever its shape, which is
        // what keeps round-robin fairness intact mid-generation and
        // across group sizes.
        let (slot_idx, unit) = {
            let mut st = relock(&shared.state);
            loop {
                if !st.paused {
                    if st.has_deadlines {
                        shed_expired(&mut st, Instant::now());
                    }
                    if let Some(idx) = next_reload(&st) {
                        // Async reload lane: claim the slot (busy) and
                        // run the artifact I/O outside this lock.
                        st.slots[idx].busy = true;
                        let path =
                            st.slots[idx].spill.clone().expect("loading slot has a spill path");
                        break (idx, Unit::Reload(path));
                    }
                    if let Some(idx) = next_runnable(&mut st) {
                        let n = st.slots.len();
                        st.rr = (idx + 1) % n;
                        let id = st.slots[idx].id;
                        let mode;
                        {
                            let slot = &mut st.slots[idx];
                            slot.busy = true;
                            if slot.queue.front().map(job_is_gen) == Some(true) {
                                // Generation group: the maximal run of
                                // consecutive generations at the front.
                                mode = DispatchMode::GenGroup;
                                while jobs.len() < decode_batch
                                    && slot.queue.front().map(job_is_gen) == Some(true)
                                {
                                    jobs.push(slot.queue.pop_front().unwrap());
                                }
                                slot.gens_inflight = jobs.len();
                            } else {
                                // Eval coalescing (opt-in): the front run
                                // of evals agreeing on seq length and
                                // target kind merges into one forward.
                                let head = if coalesce_eval {
                                    slot.queue
                                        .front()
                                        .and_then(eval_batch_of)
                                        .filter(|b| b.batch > 0)
                                        .map(|b| (b.seq, std::mem::discriminant(&b.target)))
                                } else {
                                    None
                                };
                                if let Some((seq0, disc0)) = head {
                                    while jobs.len() < decode_batch {
                                        match slot.queue.front() {
                                            Some(j) if coalesces_with(j, seq0, disc0) => {
                                                jobs.push(slot.queue.pop_front().unwrap());
                                            }
                                            _ => break,
                                        }
                                    }
                                }
                                if jobs.len() >= 2 {
                                    mode = DispatchMode::EvalGroup;
                                } else {
                                    // Not coalescable (or a single eval):
                                    // fall back to the one-shot burst.
                                    mode = DispatchMode::Oneshot;
                                    while jobs.len() < burst {
                                        match slot.queue.front() {
                                            Some(j) if !job_is_gen(j) => {
                                                jobs.push(slot.queue.pop_front().unwrap());
                                            }
                                            _ => break,
                                        }
                                    }
                                }
                            }
                        }
                        st.queued -= jobs.len();
                        // Record per entry up to the configured cap (never
                        // past `trace_cap`, so pushes never reallocate and
                        // the trace has no mid-stream gaps). A group
                        // dispatch — generations or coalesced evals —
                        // records ONE entry.
                        let trace_units = match mode {
                            DispatchMode::Oneshot => jobs.len(),
                            DispatchMode::GenGroup | DispatchMode::EvalGroup => 1,
                        };
                        if st.trace.len() < st.trace_cap {
                            let room = st.trace_cap - st.trace.len();
                            for _ in 0..trace_units.min(room) {
                                st.trace.push(id);
                            }
                        }
                        // Merged slots dispatch their dense twin for
                        // eval/generate work (train never reaches a
                        // merged slot — submit refuses it typed). The
                        // adapted backend stays in place; `busy` already
                        // excludes a second dispatch of this slot.
                        let use_merged =
                            st.slots[idx].merged && st.slots[idx].merged_backend.is_some();
                        let backend = if use_merged {
                            st.slots[idx]
                                .merged_backend
                                .take()
                                .expect("merged slot has its twin")
                        } else {
                            st.slots[idx].backend.take().expect("runnable slot has its backend")
                        };
                        break (idx, Unit::Compute(backend, mode, use_merged));
                    }
                }
                if st.shutdown && st.queued == 0 {
                    return;
                }
                st = rewait(&shared.work, st);
            }
        };
        let (mut backend, mode, used_merged) = match unit {
            Unit::Reload(path) => {
                run_reload(shared, &cfg, slot_idx, path);
                continue;
            }
            Unit::Compute(backend, mode, used_merged) => (backend, mode, used_merged),
        };

        // Service the dispatch unit outside the scheduler lock; other
        // workers keep dispatching other adapters meanwhile. Panics are
        // CONTAINED at this boundary: no scheduler lock is held during
        // compute, so a panicking adapter can neither poison it nor kill
        // the worker — the catch below retires the offending adapter,
        // fails its tickets with `WorkerPanicked`, and the worker keeps
        // serving.
        let mut done = 0u64;
        let mut train_steps = 0u64;
        let mut tokens_generated = 0u64;
        let mut service_ns = 0u64;
        let mut latency_ns = 0u64;
        let mut max_latency_ns = 0u64;
        let mut group_dispatches = 0u64;
        let mut group_lanes = 0u64;
        let mut prefill_chunks = 0u64;
        let mut prefill_tokens = 0u64;
        // Mean per-emitted-token service time of this dispatch (gen
        // groups only); one sketch sample per dispatch.
        let mut per_token_ns = 0u64;
        ttft_samples.clear();
        // Ticket of the job being finalized right now (failed on panic).
        let mut current: Option<Arc<TicketInner>> = None;
        let panicked = catch_unwind(AssertUnwindSafe(|| match mode {
            DispatchMode::GenGroup => {
                let n_group = jobs.len();
                group_dispatches = 1;
                group_lanes = n_group as u64;
                let svc = Instant::now();
                // Join every lane: fresh generations take pooled rings
                // (reset); resumed ones carry theirs from the last
                // dispatch. The stream cursor moves into the group for
                // the burst and back out after it.
                for job in jobs.iter_mut() {
                    let JobKind::Gen(gen) = &mut job.kind else {
                        unreachable!("generation group holds generation jobs")
                    };
                    let (mut kv, fresh_gen) = match gen.lane.take() {
                        Some(kv) => (kv, false),
                        None => (lane_pool.pop().unwrap_or_default(), true),
                    };
                    kv.ensure(&backend.model, &mut ws);
                    if fresh_gen {
                        kv.reset();
                    }
                    let stream = std::mem::replace(&mut gen.stream, native::DecodeStream::new(&[]));
                    gc.join(kv, stream, Arc::clone(&gen.prompt), gen.max_new_tokens, gen.greedy);
                }
                for f in fresh.iter_mut() {
                    f.clear();
                }
                // ≤ `burst` lockstep steps for the whole group (prompt-
                // phase lanes consume chunked batched prefill instead of
                // lockstep rows). A typed decode overflow — unreachable
                // past submit's validation, but never a panic — fails
                // the whole group's tickets below.
                let overflow = match gc
                    .advance(&backend.model, burst, &mut ws, &mut fresh[..n_group])
                {
                    Ok(_) => None,
                    Err(native::DecodeError::PastMaxSeq { pos: _, max_seq }) => Some(max_seq),
                };
                let (pf_chunks, pf_tokens) = gc.take_prefill_counters();
                prefill_chunks += pf_chunks;
                prefill_tokens += pf_tokens;
                let group_svc = svc.elapsed().as_nanos() as u64;
                service_ns += group_svc;
                // Leave the group in join order: stream fresh tokens,
                // complete finished lanes (pages back to the pool),
                // collect unfinished ones for the front re-enqueue.
                for li in 0..n_group {
                    let mut job = jobs.remove(0);
                    current = Some(Arc::clone(&job.ticket));
                    let (mut kv, stream, job_done) =
                        gc.detach_first().expect("one joined lane per group job");
                    let JobKind::Gen(gen) = &mut job.kind else {
                        unreachable!("generation group holds generation jobs")
                    };
                    gen.stream = stream;
                    if let Some(max_seq) = overflow {
                        // The group's step schedule is shared, so every
                        // lane fails the same typed way — each with its
                        // OWN prompt/max_new numbers so a client can
                        // clamp and retry; its pages recycle immediately.
                        kv.free_pages(&mut ws);
                        lane_pool.push(kv);
                        fail(
                            &job.ticket,
                            ServeError::DecodeOverflow {
                                prompt: gen.prompt.len(),
                                max_new: gen.max_new_tokens,
                                max_seq,
                            },
                        );
                        current = None;
                        continue;
                    }
                    let emitted = &fresh[li];
                    tokens_generated += emitted.len() as u64;
                    if !emitted.is_empty() {
                        if gen.emitted == 0 {
                            // First token of this generation: its TTFT.
                            ttft_samples
                                .push((job.enqueued.elapsed().as_nanos() as u64, job.tier));
                        }
                        gen.emitted += emitted.len();
                        stream_tokens(&job.ticket, emitted);
                    }
                    if job_done {
                        // Every page back to the pool before the lane
                        // parks: a pooled idle lane holds no K/V memory.
                        kv.free_pages(&mut ws);
                        lane_pool.push(kv);
                        complete_gen(&job.ticket);
                        done += 1;
                        let lat = job.enqueued.elapsed().as_nanos() as u64;
                        latency_ns += lat;
                        max_latency_ns = max_latency_ns.max(lat);
                    } else {
                        gen.lane = Some(kv);
                        requeue.push(job);
                    }
                    current = None;
                }
                if tokens_generated > 0 {
                    per_token_ns = group_svc / tokens_generated;
                }
            }
            DispatchMode::EvalGroup => {
                let n_group = jobs.len();
                group_dispatches = 1;
                group_lanes = n_group as u64;
                let svc = Instant::now();
                // Concatenate the requests along the batch axis into the
                // reusable merged batch (vectors keep their capacity
                // across dispatches; the target vector is reused when the
                // kind matches the previous dispatch).
                spans.clear();
                merged.tokens.clear();
                merged.pad.clear();
                merged.batch = 0;
                {
                    let head = eval_batch_of(&jobs[0]).expect("eval group holds eval jobs");
                    merged.seq = head.seq;
                    match (&mut merged.target, &head.target) {
                        (Target::Class(m), Target::Class(_)) => m.clear(),
                        (Target::Reg(m), Target::Reg(_)) => m.clear(),
                        (Target::LmMask(m), Target::LmMask(_)) => m.clear(),
                        (t, Target::Class(_)) => *t = Target::Class(Vec::new()),
                        (t, Target::Reg(_)) => *t = Target::Reg(Vec::new()),
                        (t, Target::LmMask(_)) => *t = Target::LmMask(Vec::new()),
                    }
                }
                for job in jobs.iter() {
                    let b = eval_batch_of(job).expect("eval group holds eval jobs");
                    merged.batch += b.batch;
                    merged.tokens.extend_from_slice(&b.tokens);
                    merged.pad.extend_from_slice(&b.pad);
                    match (&mut merged.target, &b.target) {
                        (Target::Class(m), Target::Class(v)) => m.extend_from_slice(v),
                        (Target::Reg(m), Target::Reg(v)) => m.extend_from_slice(v),
                        (Target::LmMask(m), Target::LmMask(v)) => m.extend_from_slice(v),
                        _ => unreachable!("coalesced evals share a target kind"),
                    }
                    spans.push(b.batch);
                }
                native::evaluate_grouped_into(
                    &backend.model,
                    &merged,
                    &spans,
                    &mut backend.bufs,
                    &mut ws,
                );
                service_ns += svc.elapsed().as_nanos() as u64;
                // Scatter per-request (loss, metric, preds) back to the
                // tickets — bit-identical to uncoalesced evaluation.
                let mut b0 = 0usize;
                for ri in 0..n_group {
                    let job = jobs.remove(0);
                    current = Some(Arc::clone(&job.ticket));
                    let nb = spans[ri];
                    let (l, m) = backend.bufs.span_results[ri];
                    complete(&job.ticket, l, m, &backend.bufs.preds[b0..b0 + nb]);
                    b0 += nb;
                    done += 1;
                    let lat = job.enqueued.elapsed().as_nanos() as u64;
                    latency_ns += lat;
                    max_latency_ns = max_latency_ns.max(lat);
                    ttft_samples.push((lat, job.tier));
                    current = None;
                }
            }
            DispatchMode::Oneshot => {
                while !jobs.is_empty() {
                    let job = jobs.remove(0);
                    current = Some(Arc::clone(&job.ticket));
                    let svc = Instant::now();
                    let JobKind::Batch { ref batch, req } = job.kind else {
                        unreachable!("one-shot dispatches hold batch jobs")
                    };
                    let (loss, metric) = match req {
                        ReqKind::Eval => native::evaluate_into(
                            &backend.model,
                            batch,
                            &mut backend.bufs,
                            &mut ws,
                        ),
                        ReqKind::Train(hyper) => {
                            train_steps += 1;
                            backend.step_core(batch, &hyper, &mut ws)
                        }
                    };
                    complete(&job.ticket, loss, metric, &backend.bufs.preds);
                    current = None;
                    service_ns += svc.elapsed().as_nanos() as u64;
                    done += 1;
                    let lat = job.enqueued.elapsed().as_nanos() as u64;
                    latency_ns += lat;
                    max_latency_ns = max_latency_ns.max(lat);
                    ttft_samples.push((lat, job.tier));
                }
            }
        }))
        .is_err();

        if panicked {
            // The adapter's state may be mid-update — retire it (its
            // backend is dropped, queued and in-flight requests fail with
            // the typed error) and keep the worker and every other
            // adapter serving. The scheduler mutex was NOT held across
            // the panic, so no lock is poisoned. Group state may be
            // mid-join/mid-burst, so the worker's group cache is rebuilt
            // from scratch (its buffers are simply dropped — later
            // dispatches re-acquire from the workspace pool).
            let mut failed: Vec<Arc<TicketInner>> = Vec::new();
            if let Some(t) = current.take() {
                failed.push(t);
            }
            // Free every K/V page this dispatch still has checked out
            // BEFORE failing the tickets: lanes parked in the group
            // cache (panic mid-burst), lanes still attached to group
            // jobs not yet joined or already collected for the requeue.
            // A contained panic must not leak pool pages — the
            // containment tests pin `pages_outstanding` back to zero.
            gc.release(&mut ws);
            gc.set_prefill_chunk(cfg.prefill_chunk);
            for job in jobs.drain(..).chain(requeue.drain(..)) {
                let Job { kind, ticket, .. } = job;
                if let JobKind::Gen(mut gen) = kind {
                    if let Some(mut kv) = gen.lane.take() {
                        kv.free_pages(&mut ws);
                        lane_pool.push(kv);
                    }
                }
                failed.push(ticket);
            }
            {
                let mut st = relock(&shared.state);
                st.worker_panics += 1;
                let n_queued = st.slots[slot_idx].queue.len();
                st.queued -= n_queued;
                let slot = &mut st.slots[slot_idx];
                crate::warn_log!(
                    "serve worker panic while running adapter {}; retiring it",
                    slot.id
                );
                slot.live = false;
                slot.busy = false;
                slot.gens_inflight = 0;
                slot.draining = false;
                slot.loading = false;
                // Queued jobs can carry re-enqueued lanes from an
                // earlier dispatch of this slot. Free their pages too
                // (pages recycle across workers exactly as they do on
                // the normal completion path) before the tickets fail.
                while let Some(job) = slot.queue.pop_front() {
                    let Job { kind, ticket, .. } = job;
                    if let JobKind::Gen(mut gen) = kind {
                        if let Some(mut kv) = gen.lane.take() {
                            kv.free_pages(&mut ws);
                            lane_pool.push(kv);
                        }
                    }
                    failed.push(ticket);
                }
                // The retired slot's state is dropped wholesale — the
                // merged twin with it.
                slot.merged_backend = None;
                slot.merged = false;
                if let Some(p) = slot.spill.take() {
                    remove_spill_file(&p, "panic-retire");
                }
                st.pages_outstanding[cfg.index] = ws.page_pool().outstanding();
            }
            shared.work.notify_all();
            shared.idle.notify_all();
            for t in &failed {
                fail(t, ServeError::WorkerPanicked);
            }
            drop(backend);
            continue;
        }

        // Put the adapter state back, re-enqueue unfinished generations
        // (front of the queue, original order preserved: round-robin
        // moves on to other adapters in between, and the lanes re-group
        // at their next dispatch), and publish stats. If the slot was
        // evicted while we computed, the orphaned generations fail with
        // `Evicted` (outside the lock).
        let orphaned = {
            let mut st = relock(&shared.state);
            let live = st.slots[slot_idx].live;
            if live && !requeue.is_empty() {
                let n_re = requeue.len();
                {
                    let slot = &mut st.slots[slot_idx];
                    for job in requeue.drain(..).rev() {
                        slot.queue.push_front(job);
                    }
                }
                st.queued += n_re;
            }
            let slot = &mut st.slots[slot_idx];
            // Restore the backend to the field it was borrowed from:
            // the merged twin never overwrites the adapted source of
            // truth. (A demote that raced this dispatch waited on
            // `busy`, so the twin cannot resurrect a dropped mode —
            // demote runs after this put-back and drops it again.)
            if used_merged {
                slot.merged_backend = Some(backend);
                slot.stats.merged_tokens += tokens_generated;
            } else {
                slot.backend = Some(backend);
            }
            slot.busy = false;
            slot.gens_inflight = 0;
            slot.stats.processed += done;
            slot.stats.train_steps += train_steps;
            slot.stats.tokens_generated += tokens_generated;
            slot.stats.service_ns += service_ns;
            slot.stats.total_latency_ns += latency_ns;
            slot.stats.max_latency_ns = slot.stats.max_latency_ns.max(max_latency_ns);
            slot.stats.group_dispatches += group_dispatches;
            slot.stats.group_lanes += group_lanes;
            slot.stats.max_group_size = slot.stats.max_group_size.max(group_lanes);
            slot.stats.prefill_chunks += prefill_chunks;
            slot.stats.prefill_tokens += prefill_tokens;
            for &(v, tier) in ttft_samples.iter() {
                slot.stats.ttft.record(v);
                slot.stats.ttft_tiered[tier.min(1)].record(v);
            }
            if per_token_ns > 0 {
                slot.stats.tok_latency.record(per_token_ns);
            }
            // Publish this worker's live-page count: nonzero while its
            // generations still hold K/V across dispatches, summing to
            // zero fleet-wide once every lane has completed.
            st.pages_outstanding[cfg.index] = ws.page_pool().outstanding();
            !live
        };
        shared.work.notify_all();
        shared.idle.notify_all();
        if orphaned {
            for job in requeue.drain(..) {
                fail(&job.ticket, ServeError::Evicted);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Arch, MethodKind, ModelConfig, ModuleKind};
    use crate::model::native::Target;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            arch: Arch::Encoder,
            vocab_size: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            max_seq: 10,
            n_classes: 2,
        }
    }

    fn tiny_batch(cfg: &ModelConfig, seed: u64) -> Arc<Batch> {
        let mut rng = Rng::new(seed);
        let (bsz, seq) = (2usize, 6usize);
        let tokens: Vec<i32> = (0..bsz * seq).map(|_| rng.below(cfg.vocab_size) as i32).collect();
        let labels: Vec<usize> = (0..bsz).map(|b| (tokens[b * seq] as usize) % 2).collect();
        Arc::new(Batch {
            batch: bsz,
            seq,
            tokens,
            pad: vec![1.0; bsz * seq],
            target: Target::Class(labels),
        })
    }

    fn lora_peft() -> PeftConfig {
        PeftConfig::new(MethodKind::Lora, 3).with_modules(vec![ModuleKind::Q, ModuleKind::V])
    }

    fn submit_eval(core: &ServeCore, id: AdapterId, batch: &Arc<Batch>, t: &Ticket) -> Admission {
        core.submit(id, Request::Eval { batch: Arc::clone(batch) }, t, SubmitOptions::default())
    }

    fn submit_train(core: &ServeCore, id: AdapterId, batch: &Arc<Batch>, t: &Ticket) -> Admission {
        core.submit(
            id,
            Request::Train { batch: Arc::clone(batch), hyper: Hyper::default() },
            t,
            SubmitOptions::default(),
        )
    }

    fn submit_gen(
        core: &ServeCore,
        id: AdapterId,
        prompt: &Arc<Vec<i32>>,
        max_new_tokens: usize,
        t: &Ticket,
    ) -> Admission {
        core.submit(
            id,
            Request::Generate { prompt: Arc::clone(prompt), max_new_tokens, greedy: true },
            t,
            SubmitOptions::default(),
        )
    }

    #[test]
    fn eval_roundtrip_matches_direct_backend() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(901);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let opts = ServeOptions { workers: 2, trace_cap: 0, ..Default::default() };
        let core = ServeCore::new(Arc::clone(&bb), opts);
        let id = core.register("lora_r3", &lora_peft(), 7);

        // Direct reference: same construction path, no serving.
        let mut direct = NativeBackend::for_adapter(&bb, &lora_peft(), 7);
        let batch = tiny_batch(&cfg, 11);
        let mut ws = Workspace::new();
        let (ref_loss, ref_metric) =
            native::evaluate_into(&direct.model, &batch, &mut direct.bufs, &mut ws);

        let ticket = Ticket::new(batch.batch);
        assert!(submit_eval(&core, id, &batch, &ticket).is_admitted());
        let (loss, metric) = ticket.wait().unwrap();
        assert_eq!(loss, ref_loss);
        assert_eq!(metric, ref_metric);
        ticket.with_preds(|p| assert_eq!(p, &direct.bufs.preds[..]));

        let stats = core.stats(id).unwrap();
        assert_eq!(stats.processed, 1);
        assert_eq!(stats.train_steps, 0);
    }

    #[test]
    fn evict_returns_state_and_fails_queued_requests() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(902);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let opts =
            ServeOptions { workers: 1, start_paused: true, queue_cap: 8, ..Default::default() };
        let core = ServeCore::new(Arc::clone(&bb), opts);
        let id = core.register("lora_r3", &lora_peft(), 7);
        let batch = tiny_batch(&cfg, 12);
        let ticket = Ticket::new(batch.batch);
        assert!(submit_eval(&core, id, &batch, &ticket).is_admitted());

        // Paused ⇒ the job is still queued; strict evict must refuse and
        // report exactly how many requests are pending.
        assert!(matches!(core.evict(id), Err(ServeError::PendingRequests(1))));

        // Explicit reject: queued requests fail, the count comes back.
        let (backend, failed) = core.evict_with(id, EvictMode::Reject).unwrap();
        assert_eq!(failed, 1);
        assert_eq!(ticket.wait(), Err(ServeError::Evicted));
        assert_eq!(core.num_adapters(), 0);
        assert_eq!(
            submit_eval(&core, id, &batch, &ticket),
            Admission::Rejected(ServeError::UnknownAdapter)
        );

        // The evicted state is intact and can be re-registered (hot swap);
        // the slot is reused rather than grown.
        let id2 = core.register_backend("lora_r3", backend);
        assert_ne!(id, id2, "adapter ids are never reused");
        core.resume();
        assert!(submit_eval(&core, id2, &batch, &ticket).is_admitted());
        assert!(ticket.wait().is_ok());

        // An idle adapter evicts strictly without complaint.
        core.drain();
        assert!(core.evict(id2).is_ok());
    }

    #[test]
    fn evict_drain_serves_queue_out_before_returning_state() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(905);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let opts =
            ServeOptions { workers: 1, start_paused: true, queue_cap: 8, ..Default::default() };
        let core = ServeCore::new(Arc::clone(&bb), opts);
        let id = core.register("lora_r3", &lora_peft(), 7);
        let batch = tiny_batch(&cfg, 14);
        let tickets: Vec<Ticket> = (0..3).map(|_| Ticket::new(batch.batch)).collect();
        for t in &tickets {
            assert!(submit_eval(&core, id, &batch, t).is_admitted());
        }
        // Drain unpauses, serves all 3, then evicts with nothing failed.
        let (backend, failed) = core.evict_with(id, EvictMode::Drain).unwrap();
        assert_eq!(failed, 0);
        for t in &tickets {
            assert!(t.wait().is_ok(), "drained requests complete normally");
        }
        assert_eq!(core.num_adapters(), 0);
        assert_eq!(backend.opt.step, 0);
    }

    #[test]
    fn checkpoint_restore_roundtrip_preserves_results() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(906);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let opts = ServeOptions { workers: 1, ..Default::default() };
        let core = ServeCore::new(Arc::clone(&bb), opts);
        let id = core.register("lora_r3", &lora_peft(), 7);
        let batch = tiny_batch(&cfg, 15);
        let ticket = Ticket::new(batch.batch);
        // A couple of train steps so the checkpoint carries real state.
        for _ in 0..2 {
            assert!(submit_train(&core, id, &batch, &ticket).is_admitted());
            ticket.wait().unwrap();
        }
        let dir = std::env::temp_dir()
            .join(format!("psoft_ckpt_test_{}", std::process::id()));
        let path = dir.join("lora_r3.psoftad");
        let bytes = core.checkpoint(id, &path).unwrap();
        assert!(bytes > 0);
        assert_eq!(core.artifact_bytes(id), Some(bytes));

        // The checkpointed adapter keeps serving...
        assert!(submit_eval(&core, id, &batch, &ticket).is_admitted());
        let (loss_orig, _) = ticket.wait().unwrap();

        // ...and its restored twin answers bit-identically.
        let id2 = core.restore("lora_r3_restored", &path).unwrap();
        assert!(submit_eval(&core, id2, &batch, &ticket).is_admitted());
        let (loss_restored, _) = ticket.wait().unwrap();
        assert_eq!(loss_orig, loss_restored, "restore must be bit-exact");
        let be = core.evict(id2).unwrap();
        assert_eq!(be.opt.step, 2, "optimizer step count survives the round-trip");
        std::fs::remove_dir_all(&dir).ok();
    }

    fn tiny_dec_cfg() -> ModelConfig {
        ModelConfig {
            arch: Arch::Decoder,
            vocab_size: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            max_seq: 12,
            n_classes: 0,
        }
    }

    #[test]
    fn generate_streams_tokens_and_matches_direct_decode() {
        let cfg = tiny_dec_cfg();
        let mut rng = Rng::new(910);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let opts = ServeOptions { workers: 2, burst: 2, ..Default::default() };
        let core = ServeCore::new(Arc::clone(&bb), opts);
        let id = core.register("lora_r3", &lora_peft(), 7);

        let prompt = Arc::new(vec![1i32, 5, 9]);
        let max_new = 6usize;
        // Direct reference: identical construction, model-level decode.
        let direct = NativeBackend::for_adapter(&bb, &lora_peft(), 7);
        let mut ws = Workspace::new();
        let mut cache = crate::model::native::DecodeCache::new();
        let mut want = Vec::new();
        crate::model::native::generate_into(
            &direct.model,
            &prompt,
            max_new,
            true,
            &mut cache,
            &mut ws,
            &mut want,
        );
        assert_eq!(want.len(), max_new);

        let ticket = Ticket::new(max_new);
        assert!(submit_gen(&core, id, &prompt, max_new, &ticket).is_admitted());
        // Stream: wait for the first token, then the rest.
        let n1 = ticket.wait_tokens(1);
        assert!(n1 >= 1);
        let (loss, metric) = ticket.wait().unwrap();
        assert_eq!(loss, 0.0);
        assert_eq!(metric, max_new as f64);
        ticket.with_tokens(|t| assert_eq!(t, &want[..], "served decode must be bit-exact"));
        let stats = core.stats(id).unwrap();
        assert_eq!(stats.processed, 1);
        assert_eq!(stats.tokens_generated, max_new as u64);
        assert!(stats.ttft.count() >= 1, "TTFT sketch sampled the generation");
        assert!(stats.ttft_ms(0.99) > 0.0);
        assert!(stats.tok_latency.count() >= 1, "per-token sketch sampled the dispatch");
    }

    #[test]
    fn generate_validation_rejects_malformed_requests() {
        let mut rng = Rng::new(911);
        // Encoder backbone: generation is meaningless.
        let enc = ServeCore::new(
            Arc::new(Backbone::random(&tiny_cfg(), &mut rng)),
            ServeOptions { workers: 1, ..Default::default() },
        );
        let id_e = enc.register("lora_r3", &lora_peft(), 7);
        let t = Ticket::new(4);
        let p = Arc::new(vec![1i32, 2]);
        assert_eq!(
            submit_gen(&enc, id_e, &p, 2, &t),
            Admission::Rejected(ServeError::InvalidRequest)
        );

        let cfg = tiny_dec_cfg();
        let core = ServeCore::new(
            Arc::new(Backbone::random(&cfg, &mut rng)),
            ServeOptions { workers: 1, ..Default::default() },
        );
        let id = core.register("lora_r3", &lora_peft(), 7);
        let empty: Arc<Vec<i32>> = Arc::new(Vec::new());
        assert_eq!(
            submit_gen(&core, id, &empty, 2, &t),
            Admission::Rejected(ServeError::InvalidRequest),
            "empty prompt"
        );
        assert_eq!(
            submit_gen(&core, id, &p, cfg.max_seq, &t),
            Admission::Rejected(ServeError::DecodeOverflow {
                prompt: 2,
                max_new: cfg.max_seq,
                max_seq: cfg.max_seq,
            }),
            "prompt + max_new past max_seq is typed with the retry numbers"
        );
        let oov = Arc::new(vec![cfg.vocab_size as i32 + 3]);
        assert_eq!(
            submit_gen(&core, id, &oov, 2, &t),
            Admission::Rejected(ServeError::InvalidRequest),
            "out-of-vocab prompt token"
        );
        // A well-formed request on the same core still works.
        assert!(submit_gen(&core, id, &p, 4, &t).is_admitted());
        assert!(t.wait().is_ok());
    }

    #[test]
    fn worker_panic_retires_adapter_not_core() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(912);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let opts = ServeOptions { workers: 1, ..Default::default() };
        let core = ServeCore::new(Arc::clone(&bb), opts);
        let bad = core.register("bad", &lora_peft(), 7);
        let good = core.register("good", &lora_peft(), 8);

        // Token id far past the vocab: the embedding gather panics on the
        // worker. The panic must surface as a typed error, not poison the
        // scheduler.
        let mut batch = (*tiny_batch(&cfg, 21)).clone();
        batch.tokens[0] = cfg.vocab_size as i32 + 1000;
        let batch = Arc::new(batch);
        let ticket = Ticket::new(batch.batch);
        assert!(submit_eval(&core, bad, &batch, &ticket).is_admitted());
        assert_eq!(ticket.wait(), Err(ServeError::WorkerPanicked));
        assert_eq!(core.worker_panics(), 1);

        // The offending adapter is retired...
        assert_eq!(core.num_adapters(), 1);
        assert_eq!(
            submit_eval(&core, bad, &tiny_batch(&cfg, 22), &ticket),
            Admission::Rejected(ServeError::UnknownAdapter)
        );
        // ...while the sibling (and the worker) keep serving normally.
        assert!(submit_eval(&core, good, &tiny_batch(&cfg, 23), &ticket).is_admitted());
        assert!(ticket.wait().is_ok());
        core.drain();
    }

    #[test]
    fn gen_worker_panic_releases_kv_pages() {
        // A worker panic mid-generation-group must free every K/V page
        // the group's lanes held — parked in the group cache or carried
        // by re-enqueued jobs — back to the pool. The backend is built
        // over a SMALLER-vocab twin backbone, so submit-time validation
        // (against the core's backbone) admits a prompt token that
        // panics the twin's embedding gather mid-decode.
        let cfg = tiny_dec_cfg();
        let mut rng = Rng::new(916);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let small_cfg = ModelConfig { vocab_size: 8, ..cfg };
        let small_bb = Arc::new(Backbone::random(&small_cfg, &mut rng));
        let opts = ServeOptions {
            workers: 1,
            start_paused: true,
            burst: 2,
            // One prompt token per lane per lockstep step: the poisoned
            // token (depth 4) is reached on the SECOND dispatch, after
            // both lanes already hold pages across a re-enqueue.
            prefill_chunk: 1,
            ..Default::default()
        };
        let core = ServeCore::new(Arc::clone(&bb), opts);
        let bad = core.register_backend(
            "bad",
            NativeBackend::for_adapter(&small_bb, &lora_peft(), 7),
        );
        // Lane A is fully valid on the twin; lane B's 4th prompt token
        // (20 ≥ twin vocab 8, < core vocab 32) passes validation and
        // panics the twin.
        let pa = Arc::new(vec![1i32, 2, 3]);
        let pb = Arc::new(vec![1i32, 2, 3, 20]);
        let (ta, tb) = (Ticket::new(4), Ticket::new(4));
        assert!(submit_gen(&core, bad, &pa, 4, &ta).is_admitted());
        assert!(submit_gen(&core, bad, &pb, 4, &tb).is_admitted());
        core.resume();
        assert_eq!(ta.wait(), Err(ServeError::WorkerPanicked));
        assert_eq!(tb.wait(), Err(ServeError::WorkerPanicked));
        assert_eq!(core.worker_panics(), 1);
        core.drain();
        assert_eq!(
            core.pages_outstanding(),
            0,
            "contained panic must not leak K/V pages"
        );
    }

    #[test]
    fn merged_mode_serves_and_refuses_train() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(917);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let core = ServeCore::new(
            Arc::clone(&bb),
            ServeOptions { workers: 1, ..Default::default() },
        );
        let id = core.register("lora_r3", &lora_peft(), 7);
        let batch = tiny_batch(&cfg, 41);
        let t = Ticket::new(batch.batch);
        // One train step so the fold has a nontrivial update to merge.
        assert!(submit_train(&core, id, &batch, &t).is_admitted());
        t.wait().unwrap();
        assert!(submit_eval(&core, id, &batch, &t).is_admitted());
        let (loss_adapted, _) = t.wait().unwrap();

        assert_eq!(core.is_merged(id), Some(false));
        core.promote(id).unwrap();
        core.promote(id).unwrap(); // idempotent
        assert_eq!(core.is_merged(id), Some(true));
        assert!(core.stats(id).unwrap().merged);

        // The merged twin serves eval within the fold tolerance.
        assert!(submit_eval(&core, id, &batch, &t).is_admitted());
        let (loss_merged, _) = t.wait().unwrap();
        assert!(
            (loss_merged - loss_adapted).abs() < 1e-3,
            "merged eval loss {loss_merged} vs adapted {loss_adapted}"
        );

        // Train needs the adapted parameterization: refused typed.
        assert_eq!(
            submit_train(&core, id, &batch, &t),
            Admission::Rejected(ServeError::MergedAdapter)
        );

        // Demote restores trainability (and the adapted dispatch path).
        core.demote(id).unwrap();
        assert_eq!(core.is_merged(id), Some(false));
        assert!(!core.stats(id).unwrap().merged);
        assert!(submit_train(&core, id, &batch, &t).is_admitted());
        t.wait().unwrap();
        core.drain();
    }

    #[test]
    fn merge_resident_auto_promotes_on_register() {
        let cfg = tiny_dec_cfg();
        let mut rng = Rng::new(918);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let core = ServeCore::new(
            Arc::clone(&bb),
            ServeOptions { workers: 1, merge_resident: true, ..Default::default() },
        );
        let id = core.register("lora_r3", &lora_peft(), 7);
        assert_eq!(core.is_merged(id), Some(true), "merge_resident promotes at registration");

        let prompt = Arc::new(vec![1i32, 5, 9]);
        let max_new = 5usize;
        let t = Ticket::new(max_new);
        assert!(submit_gen(&core, id, &prompt, max_new, &t).is_admitted());
        let (_, metric) = t.wait().unwrap();
        assert_eq!(metric, max_new as f64);
        let stats = core.stats(id).unwrap();
        assert!(stats.merged);
        assert_eq!(
            stats.merged_tokens, stats.tokens_generated,
            "every emitted token ran the merged twin"
        );
        assert_eq!(stats.tokens_generated, max_new as u64);
        assert_eq!(core.pages_outstanding(), 0, "completed generation returned its pages");

        // Merged fleets are inference-only until demoted.
        let batch = tiny_batch(&tiny_cfg(), 42);
        assert_eq!(
            core.submit(
                id,
                Request::Train { batch, hyper: Hyper::default() },
                &t,
                SubmitOptions::default(),
            ),
            Admission::Rejected(ServeError::MergedAdapter)
        );
    }

    #[test]
    fn queue_cap_rejects_and_counts() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(903);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let opts =
            ServeOptions { workers: 1, start_paused: true, queue_cap: 3, ..Default::default() };
        let core = ServeCore::new(bb, opts);
        let id = core.register("lora_r3", &lora_peft(), 7);
        let batch = tiny_batch(&cfg, 13);
        let tickets: Vec<Ticket> = (0..4).map(|_| Ticket::new(batch.batch)).collect();
        for t in &tickets[..3] {
            assert!(submit_eval(&core, id, &batch, t).is_admitted());
        }
        assert_eq!(core.queue_len(id), Some(3));
        // The typed variant carries the observed depth and the cap.
        assert_eq!(
            submit_eval(&core, id, &batch, &tickets[3]),
            Admission::Rejected(ServeError::QueueFull { depth: 3, cap: 3 })
        );
        assert_eq!(core.stats(id).unwrap().rejected, 1);
        core.drain();
        for t in &tickets[..3] {
            assert!(t.wait().is_ok());
        }
    }

    #[test]
    fn draining_submissions_carry_remaining_count() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(915);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let opts =
            ServeOptions { workers: 1, start_paused: true, queue_cap: 8, ..Default::default() };
        let core = Arc::new(ServeCore::new(bb, opts));
        let id = core.register("lora_r3", &lora_peft(), 7);
        let batch = tiny_batch(&cfg, 31);
        let tickets: Vec<Ticket> = (0..2).map(|_| Ticket::new(batch.batch)).collect();
        for t in &tickets {
            assert!(submit_eval(&core, id, &batch, t).is_admitted());
        }
        // Race a submit against the drain: the drain owns the slot, so
        // every concurrent submit must come back Draining (with however
        // many requests were left at that instant) or UnknownAdapter
        // (already fully evicted) — never silently enqueued.
        let drainer = {
            let core = Arc::clone(&core);
            thread::spawn(move || core.evict_with(id, EvictMode::Drain).unwrap())
        };
        let late = Ticket::new(batch.batch);
        loop {
            match submit_eval(&core, id, &batch, &late) {
                Admission::Rejected(ServeError::Draining { queued }) => {
                    assert!(queued <= 2, "remaining count is the observed queue depth");
                    break;
                }
                Admission::Rejected(ServeError::UnknownAdapter) => break,
                Admission::Admitted => {
                    late.wait().ok();
                }
                other => panic!("unexpected admission during drain: {other:?}"),
            }
        }
        drainer.join().unwrap();
    }

    #[test]
    fn zero_deadline_sheds_at_submit() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(916);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let opts = ServeOptions { workers: 1, start_paused: true, ..Default::default() };
        let core = ServeCore::new(bb, opts);
        let id = core.register("lora_r3", &lora_peft(), 7);
        let batch = tiny_batch(&cfg, 32);
        let ticket = Ticket::new(batch.batch);
        let adm = core.submit(
            id,
            Request::Eval { batch: Arc::clone(&batch) },
            &ticket,
            SubmitOptions::new().with_deadline(Duration::ZERO),
        );
        assert_eq!(adm, Admission::Shed(ShedReason::DeadlineExpired));
        assert_eq!(adm.into_result(), Err(ServeError::Shed(ShedReason::DeadlineExpired)));
        assert_eq!(core.stats(id).unwrap().shed, 1);
        assert_eq!(core.queue_len(id), Some(0), "shed requests are never enqueued");
    }

    #[test]
    fn wait_tokens_observes_ticket_rearm() {
        // Regression: a `wait_tokens` caller sleeping across a failure +
        // re-submit must observe the re-arm (generation counter bump)
        // instead of re-sleeping forever on the cleared token buffer.
        use std::sync::atomic::AtomicBool;
        let ticket = Ticket::new(8);
        ticket.arm();
        let stop = Arc::new(AtomicBool::new(false));
        let waiter = thread::spawn({
            let t2 = ticket.clone();
            let stop = Arc::clone(&stop);
            move || {
                let n = t2.wait_tokens(5);
                stop.store(true, Ordering::SeqCst);
                n
            }
        });
        // Let the waiter block, then re-arm until it wakes: pre-fix the
        // re-arm cleared `tokens` without a wakeup path, so the waiter
        // hung here.
        thread::sleep(Duration::from_millis(20));
        while !stop.load(Ordering::SeqCst) {
            ticket.arm();
            thread::sleep(Duration::from_millis(5));
        }
        let n = waiter.join().unwrap();
        assert_eq!(n, 0, "waiter released by the re-arm, not by token arrival");
    }
}
