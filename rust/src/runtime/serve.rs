//! Multi-adapter serving core: one shared frozen backbone, N hot-swappable
//! adapters, a fair request scheduler over a fixed worker pool.
//!
//! # Architecture
//!
//! A [`ServeCore`] owns:
//!
//! - **One `Arc<Backbone>`** — the frozen pre-trained weights, loaded once.
//!   Every registered adapter's `NativeModel` references the *same* frozen
//!   tensors (see `model`: embeddings, dense modules and the LM head are
//!   `Arc`-shared), so hosting N adapters costs N × adapter-state, not
//!   N × model. **Backbone-sharing invariant:** nothing in the serve layer
//!   ever writes through those `Arc`s — adapters mutate only their own
//!   trainable state, so registration and eviction never touch the
//!   backbone and requests to different adapters can run concurrently.
//! - **A slot table** of registered adapters. Each slot owns the full
//!   per-adapter state: the [`NativeBackend`] (adapter tensors + optimizer
//!   moments + its warm [`StepBuffers`](crate::model::native::StepBuffers))
//!   and a bounded FIFO request queue.
//! - **A fixed worker pool.** Each worker owns a warm [`Workspace`] that
//!   serves whichever adapter it picks up (the pool is shape-keyed, so
//!   adapters of different ranks coexist without reallocation once warm).
//!
//! # Scheduling
//!
//! Round-robin over slots with queued work, at most one worker per adapter
//! at a time (adapter state is mutable), up to `burst` consecutive
//! requests per dispatch to amortize cache warmth. Per-adapter queue depth
//! is capped (`queue_cap`); a full queue rejects with
//! [`ServeError::QueueFull`] — backpressure, not unbounded buffering. This
//! yields the fairness property the tests pin: with equal demand, adapters
//! are serviced in rotation regardless of arrival order.
//!
//! # Zero-allocation warm path
//!
//! A warm request round-trip — submit, dispatch, evaluate/train-step,
//! ticket completion, wait — performs **zero heap allocations**
//! (`tests/serve_alloc.rs`): queues are pre-sized `VecDeque`s, tickets are
//! reusable with pre-sized `preds` buffers, batches travel as `Arc<Batch>`
//! clones, and the compute runs the same warm-buffer hot path the trainer
//! uses.
//!
//! # Hot swap
//!
//! [`ServeCore::register`]/[`ServeCore::register_backend`] add adapters at
//! any time; [`ServeCore::evict`] fails that adapter's queued requests
//! with [`ServeError::Evicted`], waits out its in-flight burst and returns
//! the owned [`NativeBackend`] (so a fine-tuned adapter can be persisted).
//! The backbone and every other adapter are untouched throughout.

use crate::config::PeftConfig;
use crate::linalg::Workspace;
use crate::model::native::{self, Batch};
use crate::model::{Backbone, NativeModel};
use crate::peft::AdapterId;
use crate::runtime::{Hyper, NativeBackend};
use crate::util::rng::Rng;
use std::collections::VecDeque;
use std::fmt;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

/// What a request asks the adapter to do.
#[derive(Clone, Copy, Debug)]
pub enum ReqKind {
    /// Forward-only evaluation of the batch.
    Eval,
    /// One fine-tuning optimizer step on the batch.
    Train(Hyper),
}

/// Serve-layer errors. `Copy` so completed tickets can carry one without
/// allocating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The adapter's queue is at its depth cap — backpressure; retry later.
    QueueFull,
    /// No live adapter with this id.
    UnknownAdapter,
    /// The adapter was evicted before the request ran.
    Evicted,
    /// The core is shutting down.
    ShuttingDown,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let msg = match self {
            ServeError::QueueFull => "adapter queue at depth cap",
            ServeError::UnknownAdapter => "unknown adapter id",
            ServeError::Evicted => "adapter evicted before the request ran",
            ServeError::ShuttingDown => "serve core shutting down",
        };
        f.write_str(msg)
    }
}

impl std::error::Error for ServeError {}

/// Per-adapter service counters (cheap plain integers — updated without
/// allocation on the warm path).
#[derive(Clone, Copy, Debug, Default)]
pub struct AdapterStats {
    /// Requests completed (eval + train).
    pub processed: u64,
    /// Optimizer steps among them.
    pub train_steps: u64,
    /// Submissions rejected at the queue-depth cap.
    pub rejected: u64,
    /// Σ enqueue→completion nanoseconds over processed requests.
    pub total_latency_ns: u64,
    /// Worst single enqueue→completion latency.
    pub max_latency_ns: u64,
    /// Σ on-worker service nanoseconds (compute only, no queueing).
    pub service_ns: u64,
}

impl AdapterStats {
    pub fn mean_latency_ms(&self) -> f64 {
        if self.processed == 0 {
            0.0
        } else {
            self.total_latency_ns as f64 / self.processed as f64 / 1e6
        }
    }

    pub fn max_latency_ms(&self) -> f64 {
        self.max_latency_ns as f64 / 1e6
    }

    pub fn mean_service_ms(&self) -> f64 {
        if self.processed == 0 {
            0.0
        } else {
            self.service_ns as f64 / self.processed as f64 / 1e6
        }
    }
}

/// Scheduler knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Worker threads (≥ 1). Each owns a warm `Workspace`.
    pub workers: usize,
    /// Per-adapter queue depth cap (≥ 1); submissions beyond it get
    /// [`ServeError::QueueFull`].
    pub queue_cap: usize,
    /// Max consecutive requests one dispatch takes from a single adapter
    /// (≥ 1) before the round-robin cursor moves on.
    pub burst: usize,
    /// Capacity of the scheduling trace (dispatch order of adapter ids,
    /// recorded until full). 0 disables tracing; tests use it to pin
    /// round-robin fairness.
    pub trace_cap: usize,
    /// Start with dispatch paused (tests enqueue a deterministic backlog,
    /// then [`ServeCore::resume`]).
    pub start_paused: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            workers: crate::util::threadpool::default_parallelism(),
            queue_cap: 32,
            burst: 4,
            trace_cap: 0,
            start_paused: false,
        }
    }
}

/// `[serve]` config section → scheduler knobs (remaining fields keep
/// their defaults).
impl From<crate::config::ServeConfig> for ServeOptions {
    fn from(sc: crate::config::ServeConfig) -> ServeOptions {
        ServeOptions {
            workers: sc.workers,
            queue_cap: sc.queue_cap,
            burst: sc.burst,
            ..ServeOptions::default()
        }
    }
}

struct TicketState {
    done: bool,
    loss: f64,
    metric: f64,
    preds: Vec<f32>,
    error: Option<ServeError>,
}

struct TicketInner {
    state: Mutex<TicketState>,
    cv: Condvar,
}

/// Reusable completion handle for one in-flight request.
///
/// A ticket may carry **one outstanding request at a time**; `submit`
/// re-arms it. `preds` capacity is pre-sized at construction so warm
/// completions never allocate.
#[derive(Clone)]
pub struct Ticket {
    inner: Arc<TicketInner>,
}

impl Ticket {
    /// `max_preds` sizes the per-example prediction buffer (use the batch
    /// size of the requests this ticket will carry).
    pub fn new(max_preds: usize) -> Ticket {
        Ticket {
            inner: Arc::new(TicketInner {
                state: Mutex::new(TicketState {
                    done: false,
                    loss: f64::NAN,
                    metric: f64::NAN,
                    preds: Vec::with_capacity(max_preds),
                    error: None,
                }),
                cv: Condvar::new(),
            }),
        }
    }

    /// Block until the request completes; returns (loss, metric).
    pub fn wait(&self) -> Result<(f64, f64), ServeError> {
        let mut ts = self.inner.state.lock().unwrap();
        while !ts.done {
            ts = self.inner.cv.wait(ts).unwrap();
        }
        match ts.error {
            Some(e) => Err(e),
            None => Ok((ts.loss, ts.metric)),
        }
    }

    /// Completed request finished?
    pub fn is_done(&self) -> bool {
        self.inner.state.lock().unwrap().done
    }

    /// Borrow the per-example predictions of the completed request
    /// without copying them out.
    pub fn with_preds<R>(&self, f: impl FnOnce(&[f32]) -> R) -> R {
        let ts = self.inner.state.lock().unwrap();
        f(&ts.preds)
    }

    fn arm(&self) {
        let mut ts = self.inner.state.lock().unwrap();
        ts.done = false;
        ts.error = None;
        ts.preds.clear();
    }
}

fn complete(ticket: &TicketInner, loss: f64, metric: f64, preds: &[f32]) {
    let mut ts = ticket.state.lock().unwrap();
    ts.loss = loss;
    ts.metric = metric;
    ts.preds.clear();
    ts.preds.extend_from_slice(preds);
    ts.error = None;
    ts.done = true;
    drop(ts);
    ticket.cv.notify_all();
}

fn fail(ticket: &TicketInner, err: ServeError) {
    let mut ts = ticket.state.lock().unwrap();
    ts.error = Some(err);
    ts.done = true;
    drop(ts);
    ticket.cv.notify_all();
}

struct Job {
    batch: Arc<Batch>,
    kind: ReqKind,
    ticket: Arc<TicketInner>,
    enqueued: Instant,
}

struct Slot {
    id: AdapterId,
    /// Human-readable label (method/rank) for reporting.
    label: String,
    /// None while a worker runs this adapter or after eviction.
    backend: Option<NativeBackend>,
    queue: VecDeque<Job>,
    busy: bool,
    live: bool,
    stats: AdapterStats,
}

struct ServeState {
    slots: Vec<Slot>,
    /// Round-robin cursor (next slot index to consider).
    rr: usize,
    /// Total queued (not yet dispatched) jobs across slots.
    queued: usize,
    next_id: u64,
    paused: bool,
    shutdown: bool,
    /// Dispatch-order trace of adapter ids (test instrumentation),
    /// truncated at `trace_cap` entries.
    trace: Vec<AdapterId>,
    trace_cap: usize,
}

struct Shared {
    state: Mutex<ServeState>,
    /// Workers wait here for runnable slots.
    work: Condvar,
    /// Evict/drain waiters wait here for put-backs.
    idle: Condvar,
}

/// The multi-adapter serving core. See the module docs for the design.
pub struct ServeCore {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    opts: ServeOptions,
    backbone: Arc<Backbone>,
}

impl ServeCore {
    /// Spin up the worker pool over a shared frozen backbone.
    pub fn new(backbone: Arc<Backbone>, opts: ServeOptions) -> ServeCore {
        let shared = Arc::new(Shared {
            state: Mutex::new(ServeState {
                slots: Vec::new(),
                rr: 0,
                queued: 0,
                next_id: 0,
                paused: opts.start_paused,
                shutdown: false,
                trace: Vec::with_capacity(opts.trace_cap),
                trace_cap: opts.trace_cap,
            }),
            work: Condvar::new(),
            idle: Condvar::new(),
        });
        let workers = (0..opts.workers.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                let burst = opts.burst.max(1);
                thread::Builder::new()
                    .name(format!("psoft-serve-{i}"))
                    .spawn(move || worker_loop(&shared, burst))
                    .expect("spawn serve worker")
            })
            .collect();
        ServeCore { shared, workers, opts, backbone }
    }

    /// The shared frozen backbone.
    pub fn backbone(&self) -> &Arc<Backbone> {
        &self.backbone
    }

    /// Build and register a fresh adapter on the shared backbone. The
    /// construction (SVD init etc.) runs on the caller's thread; serving
    /// of already-registered adapters continues meanwhile.
    pub fn register(&self, label: &str, peft: &PeftConfig, seed: u64) -> AdapterId {
        let mut rng = Rng::new(seed);
        let model = NativeModel::from_backbone(&self.backbone, peft, &mut rng);
        self.register_backend(label, NativeBackend::new(model))
    }

    /// Register an externally built backend (e.g. a previously evicted,
    /// fine-tuned adapter being re-installed). Never touches the backbone.
    pub fn register_backend(&self, label: &str, backend: NativeBackend) -> AdapterId {
        let mut st = self.shared.state.lock().unwrap();
        let id = AdapterId(st.next_id);
        st.next_id += 1;
        let slot = Slot {
            id,
            label: label.to_string(),
            backend: Some(backend),
            queue: VecDeque::with_capacity(self.opts.queue_cap.max(1)),
            busy: false,
            live: true,
            stats: AdapterStats::default(),
        };
        // Reuse a fully-retired slot (evicted: state taken, not busy) so
        // the table doesn't grow without bound under churn.
        match st.slots.iter().position(|s| !s.live && !s.busy && s.backend.is_none()) {
            Some(i) => st.slots[i] = slot,
            None => st.slots.push(slot),
        }
        drop(st);
        self.shared.work.notify_all();
        id
    }

    /// Remove an adapter: fail its queued requests with
    /// [`ServeError::Evicted`], wait out its in-flight burst, and return
    /// the owned per-adapter state. The backbone is untouched.
    pub fn evict(&self, id: AdapterId) -> Result<NativeBackend, ServeError> {
        let mut st = self.shared.state.lock().unwrap();
        let idx = st
            .slots
            .iter()
            .position(|s| s.live && s.id == id)
            .ok_or(ServeError::UnknownAdapter)?;
        st.slots[idx].live = false;
        // Unqueue the not-yet-started jobs; their tickets are failed only
        // after the scheduler lock is released (ticket locks are never
        // taken under the state lock — see the worker's completion path).
        let mut failed: Vec<Job> = Vec::with_capacity(st.slots[idx].queue.len());
        while let Some(job) = st.slots[idx].queue.pop_front() {
            st.queued -= 1;
            failed.push(job);
        }
        while st.slots[idx].busy {
            st = self.shared.idle.wait(st).unwrap();
        }
        let backend = st.slots[idx].backend.take().expect("evicted slot retains state");
        drop(st);
        for job in failed {
            fail(&job.ticket, ServeError::Evicted);
        }
        Ok(backend)
    }

    /// Enqueue one request for `id`, re-arming `ticket` to receive the
    /// result. The ticket is re-armed only once the request is accepted —
    /// a failed submit leaves the ticket's previous completion intact.
    /// Zero-allocation on the warm path: the batch travels as an `Arc`
    /// clone and the queue is pre-sized.
    pub fn submit(
        &self,
        id: AdapterId,
        batch: &Arc<Batch>,
        kind: ReqKind,
        ticket: &Ticket,
    ) -> Result<(), ServeError> {
        let mut st = self.shared.state.lock().unwrap();
        if st.shutdown {
            return Err(ServeError::ShuttingDown);
        }
        let cap = self.opts.queue_cap.max(1);
        let slot = st
            .slots
            .iter_mut()
            .find(|s| s.live && s.id == id)
            .ok_or(ServeError::UnknownAdapter)?;
        if slot.queue.len() >= cap {
            slot.stats.rejected += 1;
            return Err(ServeError::QueueFull);
        }
        // Arm under the state lock: workers need that lock to dispatch,
        // so the job cannot complete before it is armed. (No path ever
        // holds a ticket lock and then takes the state lock, so this
        // nesting is deadlock-free.)
        ticket.arm();
        slot.queue.push_back(Job {
            batch: Arc::clone(batch),
            kind,
            ticket: Arc::clone(&ticket.inner),
            enqueued: Instant::now(),
        });
        st.queued += 1;
        drop(st);
        self.shared.work.notify_one();
        Ok(())
    }

    /// Block until every queued and in-flight request has completed.
    /// (Unpauses dispatch if the core started paused.)
    pub fn drain(&self) {
        let mut st = self.shared.state.lock().unwrap();
        if st.paused {
            st.paused = false;
            self.shared.work.notify_all();
        }
        while st.queued > 0 || st.slots.iter().any(|s| s.busy) {
            st = self.shared.idle.wait(st).unwrap();
        }
    }

    /// Start dispatching (cores built with `start_paused`).
    pub fn resume(&self) {
        let mut st = self.shared.state.lock().unwrap();
        st.paused = false;
        drop(st);
        self.shared.work.notify_all();
    }

    /// Stats for one adapter (live or already evicted, while its slot has
    /// not been reused).
    pub fn stats(&self, id: AdapterId) -> Option<AdapterStats> {
        let st = self.shared.state.lock().unwrap();
        st.slots.iter().find(|s| s.id == id).map(|s| s.stats)
    }

    /// (id, label, stats) of every live adapter, in slot order.
    pub fn adapters(&self) -> Vec<(AdapterId, String, AdapterStats)> {
        let st = self.shared.state.lock().unwrap();
        st.slots
            .iter()
            .filter(|s| s.live)
            .map(|s| (s.id, s.label.clone(), s.stats))
            .collect()
    }

    /// Number of live adapters.
    pub fn num_adapters(&self) -> usize {
        self.shared.state.lock().unwrap().slots.iter().filter(|s| s.live).count()
    }

    /// Currently queued (undispatched) requests for one adapter.
    pub fn queue_len(&self, id: AdapterId) -> Option<usize> {
        let st = self.shared.state.lock().unwrap();
        st.slots.iter().find(|s| s.live && s.id == id).map(|s| s.queue.len())
    }

    /// The recorded dispatch order (adapter id per dispatched request),
    /// up to `trace_cap` entries.
    pub fn trace(&self) -> Vec<AdapterId> {
        self.shared.state.lock().unwrap().trace.clone()
    }
}

impl Drop for ServeCore {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            st.paused = false;
        }
        self.shared.work.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn next_runnable(st: &ServeState) -> Option<usize> {
    let n = st.slots.len();
    for k in 0..n {
        let i = (st.rr + k) % n;
        let s = &st.slots[i];
        if s.live && !s.busy && s.backend.is_some() && !s.queue.is_empty() {
            return Some(i);
        }
    }
    None
}

fn worker_loop(shared: &Shared, burst: usize) {
    let mut ws = Workspace::new();
    let mut jobs: Vec<Job> = Vec::with_capacity(burst);
    loop {
        // Dispatch: pick the next runnable slot round-robin and take up to
        // `burst` of its queued jobs plus its backend.
        let (slot_idx, mut backend) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if !st.paused {
                    if let Some(idx) = next_runnable(&st) {
                        let n = st.slots.len();
                        st.rr = (idx + 1) % n;
                        let id = st.slots[idx].id;
                        {
                            let slot = &mut st.slots[idx];
                            slot.busy = true;
                            for _ in 0..burst {
                                match slot.queue.pop_front() {
                                    Some(j) => jobs.push(j),
                                    None => break,
                                }
                            }
                        }
                        st.queued -= jobs.len();
                        // Record per entry up to the configured cap (never
                        // past `trace_cap`, so pushes never reallocate and
                        // the trace has no mid-stream gaps).
                        if st.trace.len() < st.trace_cap {
                            let room = st.trace_cap - st.trace.len();
                            for _ in 0..jobs.len().min(room) {
                                st.trace.push(id);
                            }
                        }
                        let backend =
                            st.slots[idx].backend.take().expect("runnable slot has its backend");
                        break (idx, backend);
                    }
                }
                if st.shutdown && st.queued == 0 {
                    return;
                }
                st = shared.work.wait(st).unwrap();
            }
        };

        // Service the burst outside the scheduler lock; other workers keep
        // dispatching other adapters meanwhile.
        let mut done = 0u64;
        let mut train_steps = 0u64;
        let mut service_ns = 0u64;
        let mut latency_ns = 0u64;
        let mut max_latency_ns = 0u64;
        for job in jobs.drain(..) {
            let svc = Instant::now();
            let (loss, metric) = match job.kind {
                ReqKind::Eval => {
                    native::evaluate_into(&backend.model, &job.batch, &mut backend.bufs, &mut ws)
                }
                ReqKind::Train(hyper) => {
                    train_steps += 1;
                    backend.step_core(&job.batch, &hyper, &mut ws)
                }
            };
            complete(&job.ticket, loss, metric, &backend.bufs.preds);
            done += 1;
            service_ns += svc.elapsed().as_nanos() as u64;
            let lat = job.enqueued.elapsed().as_nanos() as u64;
            latency_ns += lat;
            max_latency_ns = max_latency_ns.max(lat);
        }

        // Put the adapter state back and publish stats.
        {
            let mut st = shared.state.lock().unwrap();
            let slot = &mut st.slots[slot_idx];
            slot.backend = Some(backend);
            slot.busy = false;
            slot.stats.processed += done;
            slot.stats.train_steps += train_steps;
            slot.stats.service_ns += service_ns;
            slot.stats.total_latency_ns += latency_ns;
            slot.stats.max_latency_ns = slot.stats.max_latency_ns.max(max_latency_ns);
        }
        shared.work.notify_all();
        shared.idle.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Arch, MethodKind, ModelConfig, ModuleKind};
    use crate::model::native::Target;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            arch: Arch::Encoder,
            vocab_size: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            max_seq: 10,
            n_classes: 2,
        }
    }

    fn tiny_batch(cfg: &ModelConfig, seed: u64) -> Arc<Batch> {
        let mut rng = Rng::new(seed);
        let (bsz, seq) = (2usize, 6usize);
        let tokens: Vec<i32> = (0..bsz * seq).map(|_| rng.below(cfg.vocab_size) as i32).collect();
        let labels: Vec<usize> = (0..bsz).map(|b| (tokens[b * seq] as usize) % 2).collect();
        Arc::new(Batch {
            batch: bsz,
            seq,
            tokens,
            pad: vec![1.0; bsz * seq],
            target: Target::Class(labels),
        })
    }

    fn lora_peft() -> PeftConfig {
        PeftConfig::new(MethodKind::Lora, 3).with_modules(vec![ModuleKind::Q, ModuleKind::V])
    }

    #[test]
    fn eval_roundtrip_matches_direct_backend() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(901);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let opts = ServeOptions { workers: 2, trace_cap: 0, ..Default::default() };
        let core = ServeCore::new(Arc::clone(&bb), opts);
        let id = core.register("lora_r3", &lora_peft(), 7);

        // Direct reference: same construction path, no serving.
        let mut direct = NativeBackend::for_adapter(&bb, &lora_peft(), 7);
        let batch = tiny_batch(&cfg, 11);
        let mut ws = Workspace::new();
        let (ref_loss, ref_metric) =
            native::evaluate_into(&direct.model, &batch, &mut direct.bufs, &mut ws);

        let ticket = Ticket::new(batch.batch);
        core.submit(id, &batch, ReqKind::Eval, &ticket).unwrap();
        let (loss, metric) = ticket.wait().unwrap();
        assert_eq!(loss, ref_loss);
        assert_eq!(metric, ref_metric);
        ticket.with_preds(|p| assert_eq!(p, &direct.bufs.preds[..]));

        let stats = core.stats(id).unwrap();
        assert_eq!(stats.processed, 1);
        assert_eq!(stats.train_steps, 0);
    }

    #[test]
    fn evict_returns_state_and_fails_queued_requests() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(902);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let opts =
            ServeOptions { workers: 1, start_paused: true, queue_cap: 8, ..Default::default() };
        let core = ServeCore::new(Arc::clone(&bb), opts);
        let id = core.register("lora_r3", &lora_peft(), 7);
        let batch = tiny_batch(&cfg, 12);
        let ticket = Ticket::new(batch.batch);
        core.submit(id, &batch, ReqKind::Eval, &ticket).unwrap();

        // Paused ⇒ the job is still queued; eviction must fail it.
        let backend = core.evict(id).unwrap();
        assert_eq!(ticket.wait(), Err(ServeError::Evicted));
        assert_eq!(core.num_adapters(), 0);
        assert!(core.submit(id, &batch, ReqKind::Eval, &ticket).is_err());

        // The evicted state is intact and can be re-registered (hot swap);
        // the slot is reused rather than grown.
        let id2 = core.register_backend("lora_r3", backend);
        assert_ne!(id, id2, "adapter ids are never reused");
        core.resume();
        core.submit(id2, &batch, ReqKind::Eval, &ticket).unwrap();
        assert!(ticket.wait().is_ok());
    }

    #[test]
    fn queue_cap_rejects_and_counts() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(903);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let opts =
            ServeOptions { workers: 1, start_paused: true, queue_cap: 3, ..Default::default() };
        let core = ServeCore::new(bb, opts);
        let id = core.register("lora_r3", &lora_peft(), 7);
        let batch = tiny_batch(&cfg, 13);
        let tickets: Vec<Ticket> = (0..4).map(|_| Ticket::new(batch.batch)).collect();
        for t in &tickets[..3] {
            core.submit(id, &batch, ReqKind::Eval, t).unwrap();
        }
        assert_eq!(core.queue_len(id), Some(3));
        assert_eq!(
            core.submit(id, &batch, ReqKind::Eval, &tickets[3]),
            Err(ServeError::QueueFull)
        );
        assert_eq!(core.stats(id).unwrap().rejected, 1);
        core.drain();
        for t in &tickets[..3] {
            assert!(t.wait().is_ok());
        }
    }
}
