//! Multi-adapter serving core: one shared frozen backbone, N hot-swappable
//! adapters, a fair request scheduler over a fixed worker pool.
//!
//! # Architecture
//!
//! A [`ServeCore`] owns:
//!
//! - **One `Arc<Backbone>`** — the frozen pre-trained weights, loaded once.
//!   Every registered adapter's `NativeModel` references the *same* frozen
//!   tensors (see `model`: embeddings, dense modules and the LM head are
//!   `Arc`-shared), so hosting N adapters costs N × adapter-state, not
//!   N × model. **Backbone-sharing invariant:** nothing in the serve layer
//!   ever writes through those `Arc`s — adapters mutate only their own
//!   trainable state, so registration and eviction never touch the
//!   backbone and requests to different adapters can run concurrently.
//! - **A slot table** of registered adapters. Each slot owns the full
//!   per-adapter state: the [`NativeBackend`] (adapter tensors + optimizer
//!   moments + its warm [`StepBuffers`](crate::model::native::StepBuffers))
//!   and a bounded FIFO request queue.
//! - **A fixed worker pool.** Each worker owns a warm [`Workspace`] that
//!   serves whichever adapter it picks up (the pool is shape-keyed, so
//!   adapters of different ranks coexist without reallocation once warm).
//!
//! # Scheduling
//!
//! Round-robin over slots with queued work, at most one worker per adapter
//! at a time (adapter state is mutable), up to `burst` consecutive
//! requests per dispatch to amortize cache warmth. Per-adapter queue depth
//! is capped (`queue_cap`); a full queue rejects with
//! [`ServeError::QueueFull`] — backpressure, not unbounded buffering. This
//! yields the fairness property the tests pin: with equal demand, adapters
//! are serviced in rotation regardless of arrival order.
//!
//! # Zero-allocation warm path
//!
//! A warm request round-trip — submit, dispatch, evaluate/train-step,
//! ticket completion, wait — performs **zero heap allocations**
//! (`tests/serve_alloc.rs`): queues are pre-sized `VecDeque`s, tickets are
//! reusable with pre-sized `preds` buffers, batches travel as `Arc<Batch>`
//! clones, and the compute runs the same warm-buffer hot path the trainer
//! uses.
//!
//! # Hot swap
//!
//! [`ServeCore::register`]/[`ServeCore::register_backend`] add adapters at
//! any time. Eviction semantics are explicit about pending work:
//! [`ServeCore::evict`] is *strict* — it refuses with
//! [`ServeError::PendingRequests`] (carrying the queued-request count)
//! when the adapter's queue is non-empty — while
//! [`ServeCore::evict_with`] takes an [`EvictMode`]:
//! [`EvictMode::Reject`] fails queued requests with
//! [`ServeError::Evicted`] and reports how many it failed,
//! [`EvictMode::Drain`] stops accepting new submissions, serves out the
//! queue, then evicts. Both wait out the in-flight burst and return the
//! owned [`NativeBackend`]. The backbone and every other adapter are
//! untouched throughout.
//!
//! # Persistence: checkpoint, restore, LRU evict-to-disk
//!
//! Adapters persist as versioned artifacts ([`crate::peft::artifact`]):
//!
//! - [`ServeCore::checkpoint`] snapshots a live adapter to a file without
//!   disturbing its queue.
//! - [`ServeCore::restore`] registers an adapter from a previously
//!   exported artifact (fingerprint-validated against this core's
//!   backbone).
//! - With `max_resident = N` ([`ServeOptions::max_resident`], `[serve]
//!   max_resident` in config), at most N adapters keep their state in
//!   memory: registering or reloading past the budget **spills** the
//!   least-recently-used idle adapter (empty queue, not running) to
//!   `spill_dir` and a later submit against a spilled adapter
//!   **transparently reloads** it — exact to the bit, including optimizer
//!   moments, because the artifact round-trip is exact. The budget is
//!   best-effort: busy or queued adapters are never spilled, so a burst
//!   across more than N adapters can transiently exceed it. Spill and
//!   reload run under the scheduler lock (reloads re-derive frozen
//!   tensors, which may involve an SVD) — resident adapters' *compute*
//!   proceeds, but dispatch pauses for the duration. The warm resident
//!   path is unaffected: a submit to a resident adapter only reads one
//!   `Option` and bumps an LRU counter (`tests/serve_alloc.rs` still
//!   pins zero allocations).

use crate::config::PeftConfig;
use crate::linalg::Workspace;
use crate::model::native::{self, Batch};
use crate::model::Backbone;
use crate::peft::artifact::AdapterArtifact;
use crate::peft::AdapterId;
use crate::runtime::{Hyper, NativeBackend};
use std::collections::VecDeque;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread;
use std::time::Instant;

/// What a request asks the adapter to do.
#[derive(Clone, Copy, Debug)]
pub enum ReqKind {
    /// Forward-only evaluation of the batch.
    Eval,
    /// One fine-tuning optimizer step on the batch.
    Train(Hyper),
}

/// Serve-layer errors. `Copy` so completed tickets can carry one without
/// allocating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The adapter's queue is at its depth cap — backpressure; retry later.
    QueueFull,
    /// No live adapter with this id.
    UnknownAdapter,
    /// The adapter was evicted before the request ran.
    Evicted,
    /// Strict [`ServeCore::evict`] refused: the adapter still has this
    /// many queued requests. Use [`ServeCore::evict_with`] to drain or
    /// reject them explicitly.
    PendingRequests(usize),
    /// Spilling or reloading the adapter's on-disk artifact failed.
    ArtifactFailed,
    /// The core is shutting down.
    ShuttingDown,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::QueueFull => f.write_str("adapter queue at depth cap"),
            ServeError::UnknownAdapter => f.write_str("unknown adapter id"),
            ServeError::Evicted => f.write_str("adapter evicted before the request ran"),
            ServeError::PendingRequests(n) => write!(
                f,
                "adapter has {n} pending request(s); evict_with(Drain) or evict_with(Reject) \
                 to resolve them explicitly"
            ),
            ServeError::ArtifactFailed => {
                f.write_str("adapter artifact spill/reload failed (see warning log)")
            }
            ServeError::ShuttingDown => f.write_str("serve core shutting down"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What to do with queued requests when evicting an adapter
/// ([`ServeCore::evict_with`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictMode {
    /// Fail every queued request with [`ServeError::Evicted`] immediately;
    /// the eviction result reports how many were failed.
    Reject,
    /// Stop accepting new submissions, serve the queue to completion, then
    /// evict (reported pending count is therefore 0). Like
    /// [`ServeCore::drain`], this unpauses a `start_paused` core for the
    /// whole fleet — the queue could never empty otherwise — and the core
    /// stays unpaused afterwards.
    Drain,
}

/// Per-adapter service counters (cheap plain integers — updated without
/// allocation on the warm path).
#[derive(Clone, Copy, Debug, Default)]
pub struct AdapterStats {
    /// Requests completed (eval + train).
    pub processed: u64,
    /// Optimizer steps among them.
    pub train_steps: u64,
    /// Submissions rejected at the queue-depth cap.
    pub rejected: u64,
    /// Σ enqueue→completion nanoseconds over processed requests.
    pub total_latency_ns: u64,
    /// Worst single enqueue→completion latency.
    pub max_latency_ns: u64,
    /// Σ on-worker service nanoseconds (compute only, no queueing).
    pub service_ns: u64,
}

impl AdapterStats {
    pub fn mean_latency_ms(&self) -> f64 {
        if self.processed == 0 {
            0.0
        } else {
            self.total_latency_ns as f64 / self.processed as f64 / 1e6
        }
    }

    pub fn max_latency_ms(&self) -> f64 {
        self.max_latency_ns as f64 / 1e6
    }

    pub fn mean_service_ms(&self) -> f64 {
        if self.processed == 0 {
            0.0
        } else {
            self.service_ns as f64 / self.processed as f64 / 1e6
        }
    }
}

/// Scheduler knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Worker threads (≥ 1). Each owns a warm `Workspace`.
    pub workers: usize,
    /// Per-adapter queue depth cap (≥ 1); submissions beyond it get
    /// [`ServeError::QueueFull`].
    pub queue_cap: usize,
    /// Max consecutive requests one dispatch takes from a single adapter
    /// (≥ 1) before the round-robin cursor moves on.
    pub burst: usize,
    /// Capacity of the scheduling trace (dispatch order of adapter ids,
    /// recorded until full). 0 disables tracing; tests use it to pin
    /// round-robin fairness.
    pub trace_cap: usize,
    /// Start with dispatch paused (tests enqueue a deterministic backlog,
    /// then [`ServeCore::resume`]).
    pub start_paused: bool,
    /// Resident-adapter budget: past this many in-memory adapters, the
    /// least-recently-used idle adapter spills to disk and reloads
    /// transparently on its next submit. 0 disables eviction (default).
    pub max_resident: usize,
    /// Directory for spilled artifacts. `None` (default) picks a unique
    /// per-core directory under the system temp dir.
    pub spill_dir: Option<PathBuf>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            workers: crate::util::threadpool::default_parallelism(),
            queue_cap: 32,
            burst: 4,
            trace_cap: 0,
            start_paused: false,
            max_resident: 0,
            spill_dir: None,
        }
    }
}

/// `[serve]` config section → scheduler knobs (remaining fields keep
/// their defaults).
impl From<crate::config::ServeConfig> for ServeOptions {
    fn from(sc: crate::config::ServeConfig) -> ServeOptions {
        ServeOptions {
            workers: sc.workers,
            queue_cap: sc.queue_cap,
            burst: sc.burst,
            max_resident: sc.max_resident,
            ..ServeOptions::default()
        }
    }
}

struct TicketState {
    done: bool,
    loss: f64,
    metric: f64,
    preds: Vec<f32>,
    error: Option<ServeError>,
}

struct TicketInner {
    state: Mutex<TicketState>,
    cv: Condvar,
}

/// Reusable completion handle for one in-flight request.
///
/// A ticket may carry **one outstanding request at a time**; `submit`
/// re-arms it. `preds` capacity is pre-sized at construction so warm
/// completions never allocate.
#[derive(Clone)]
pub struct Ticket {
    inner: Arc<TicketInner>,
}

impl Ticket {
    /// `max_preds` sizes the per-example prediction buffer (use the batch
    /// size of the requests this ticket will carry).
    pub fn new(max_preds: usize) -> Ticket {
        Ticket {
            inner: Arc::new(TicketInner {
                state: Mutex::new(TicketState {
                    done: false,
                    loss: f64::NAN,
                    metric: f64::NAN,
                    preds: Vec::with_capacity(max_preds),
                    error: None,
                }),
                cv: Condvar::new(),
            }),
        }
    }

    /// Block until the request completes; returns (loss, metric).
    pub fn wait(&self) -> Result<(f64, f64), ServeError> {
        let mut ts = self.inner.state.lock().unwrap();
        while !ts.done {
            ts = self.inner.cv.wait(ts).unwrap();
        }
        match ts.error {
            Some(e) => Err(e),
            None => Ok((ts.loss, ts.metric)),
        }
    }

    /// Completed request finished?
    pub fn is_done(&self) -> bool {
        self.inner.state.lock().unwrap().done
    }

    /// Borrow the per-example predictions of the completed request
    /// without copying them out.
    pub fn with_preds<R>(&self, f: impl FnOnce(&[f32]) -> R) -> R {
        let ts = self.inner.state.lock().unwrap();
        f(&ts.preds)
    }

    fn arm(&self) {
        let mut ts = self.inner.state.lock().unwrap();
        ts.done = false;
        ts.error = None;
        ts.preds.clear();
    }
}

fn complete(ticket: &TicketInner, loss: f64, metric: f64, preds: &[f32]) {
    let mut ts = ticket.state.lock().unwrap();
    ts.loss = loss;
    ts.metric = metric;
    ts.preds.clear();
    ts.preds.extend_from_slice(preds);
    ts.error = None;
    ts.done = true;
    drop(ts);
    ticket.cv.notify_all();
}

fn fail(ticket: &TicketInner, err: ServeError) {
    let mut ts = ticket.state.lock().unwrap();
    ts.error = Some(err);
    ts.done = true;
    drop(ts);
    ticket.cv.notify_all();
}

struct Job {
    batch: Arc<Batch>,
    kind: ReqKind,
    ticket: Arc<TicketInner>,
    enqueued: Instant,
}

struct Slot {
    id: AdapterId,
    /// Human-readable label (method/rank) for reporting.
    label: String,
    /// None while a worker runs this adapter, while the state is spilled
    /// to disk, or after eviction.
    backend: Option<NativeBackend>,
    queue: VecDeque<Job>,
    busy: bool,
    live: bool,
    /// Evict-with-drain in progress: new submissions are refused while the
    /// queue serves out.
    draining: bool,
    /// Spilled-to-disk artifact. Invariant for live slots: `spill` is
    /// `Some` iff the state is neither resident (`backend`) nor running
    /// (`busy`); spilled slots always have an empty queue (submits reload
    /// before enqueueing).
    spill: Option<PathBuf>,
    /// Logical LRU timestamp (scheduler clock at the last submit).
    last_used: u64,
    /// Size of this adapter's artifact encoding, cached at registration
    /// and refreshed by checkpoint/spill (reporting: bytes-per-adapter).
    artifact_bytes: u64,
    stats: AdapterStats,
}

struct ServeState {
    slots: Vec<Slot>,
    /// Round-robin cursor (next slot index to consider).
    rr: usize,
    /// Total queued (not yet dispatched) jobs across slots.
    queued: usize,
    next_id: u64,
    /// Logical clock driving the LRU spill order.
    clock: u64,
    paused: bool,
    shutdown: bool,
    /// Dispatch-order trace of adapter ids (test instrumentation),
    /// truncated at `trace_cap` entries.
    trace: Vec<AdapterId>,
    trace_cap: usize,
}

struct Shared {
    state: Mutex<ServeState>,
    /// Workers wait here for runnable slots.
    work: Condvar,
    /// Evict/drain waiters wait here for put-backs.
    idle: Condvar,
}

/// Monotonic suffix so concurrent cores in one process get distinct
/// default spill directories.
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// The multi-adapter serving core. See the module docs for the design.
pub struct ServeCore {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    opts: ServeOptions,
    backbone: Arc<Backbone>,
    /// Resolved directory spilled artifacts are written to.
    spill_dir: PathBuf,
}

impl ServeCore {
    /// Spin up the worker pool over a shared frozen backbone.
    pub fn new(backbone: Arc<Backbone>, opts: ServeOptions) -> ServeCore {
        let spill_dir = opts.spill_dir.clone().unwrap_or_else(|| {
            std::env::temp_dir().join(format!(
                "psoft_spill_{}_{}",
                std::process::id(),
                SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
            ))
        });
        let shared = Arc::new(Shared {
            state: Mutex::new(ServeState {
                slots: Vec::new(),
                rr: 0,
                queued: 0,
                next_id: 0,
                clock: 0,
                paused: opts.start_paused,
                shutdown: false,
                trace: Vec::with_capacity(opts.trace_cap),
                trace_cap: opts.trace_cap,
            }),
            work: Condvar::new(),
            idle: Condvar::new(),
        });
        let workers = (0..opts.workers.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                let burst = opts.burst.max(1);
                thread::Builder::new()
                    .name(format!("psoft-serve-{i}"))
                    .spawn(move || worker_loop(&shared, burst))
                    .expect("spawn serve worker")
            })
            .collect();
        ServeCore { shared, workers, opts, backbone, spill_dir }
    }

    /// The shared frozen backbone.
    pub fn backbone(&self) -> &Arc<Backbone> {
        &self.backbone
    }

    /// Build and register a fresh adapter on the shared backbone. The
    /// construction (SVD init etc.) runs on the caller's thread; serving
    /// of already-registered adapters continues meanwhile. The seed is
    /// recorded on the backend so spill/checkpoint artifacts can re-derive
    /// the frozen adapter tensors exactly.
    pub fn register(&self, label: &str, peft: &PeftConfig, seed: u64) -> AdapterId {
        self.register_backend(label, NativeBackend::for_adapter(&self.backbone, peft, seed))
    }

    /// Register an externally built backend (e.g. a previously evicted,
    /// fine-tuned adapter being re-installed, or one restored from an
    /// artifact). Never touches the backbone. Past the resident budget,
    /// the least-recently-used idle adapter spills to disk. Backends
    /// without a recorded construction seed (or in pretraining mode) are
    /// accepted but never spilled — their frozen tensors could not be
    /// reconstructed on reload.
    pub fn register_backend(&self, label: &str, backend: NativeBackend) -> AdapterId {
        // Arithmetic size of the artifact encoding (no serialization) —
        // reporting reads this cached value instead of re-encoding live
        // state; 0 for non-exportable backends.
        let artifact_bytes = if backend.artifact_exportable() {
            backend.artifact_encoded_len(label) as u64
        } else {
            0
        };
        let mut st = self.shared.state.lock().unwrap();
        let id = AdapterId(st.next_id);
        st.next_id += 1;
        st.clock += 1;
        let slot = Slot {
            id,
            label: label.to_string(),
            backend: Some(backend),
            queue: VecDeque::with_capacity(self.opts.queue_cap.max(1)),
            busy: false,
            live: true,
            draining: false,
            spill: None,
            last_used: st.clock,
            artifact_bytes,
            stats: AdapterStats::default(),
        };
        // Reuse a fully-retired slot (evicted: state taken, not busy) so
        // the table doesn't grow without bound under churn.
        let idx = match st
            .slots
            .iter()
            .position(|s| !s.live && !s.busy && s.backend.is_none() && s.spill.is_none())
        {
            Some(i) => {
                st.slots[i] = slot;
                i
            }
            None => {
                st.slots.push(slot);
                st.slots.len() - 1
            }
        };
        self.spill_down_to(&mut st, self.opts.max_resident, Some(idx));
        drop(st);
        self.shared.work.notify_all();
        id
    }

    /// Strict eviction: remove an idle adapter, wait out its in-flight
    /// burst, and return the owned per-adapter state. Refuses with
    /// [`ServeError::PendingRequests`] (carrying the queued count) when
    /// requests are still queued — callers must pick a policy via
    /// [`ServeCore::evict_with`]. The backbone is untouched.
    pub fn evict(&self, id: AdapterId) -> Result<NativeBackend, ServeError> {
        self.evict_impl(id, true, false).map(|(backend, _)| backend)
    }

    /// Evict with an explicit policy for queued requests; returns the
    /// owned state and how many pending requests were failed (always 0
    /// for [`EvictMode::Drain`]).
    pub fn evict_with(
        &self,
        id: AdapterId,
        mode: EvictMode,
    ) -> Result<(NativeBackend, usize), ServeError> {
        match mode {
            EvictMode::Reject => self.evict_impl(id, false, false),
            EvictMode::Drain => self.evict_impl(id, false, true),
        }
    }

    fn evict_impl(
        &self,
        id: AdapterId,
        strict: bool,
        drain: bool,
    ) -> Result<(NativeBackend, usize), ServeError> {
        let mut st = self.shared.state.lock().unwrap();
        let idx = st
            .slots
            .iter()
            .position(|s| s.live && s.id == id)
            .ok_or(ServeError::UnknownAdapter)?;
        if st.slots[idx].draining {
            // Another evict_with(Drain) owns this slot already.
            return Err(ServeError::Evicted);
        }
        if strict && !st.slots[idx].queue.is_empty() {
            return Err(ServeError::PendingRequests(st.slots[idx].queue.len()));
        }
        if drain {
            // Refuse new submissions, let dispatch serve the queue out.
            st.slots[idx].draining = true;
            if st.paused {
                st.paused = false;
                self.shared.work.notify_all();
            }
            while st.slots[idx].live
                && st.slots[idx].id == id
                && (!st.slots[idx].queue.is_empty() || st.slots[idx].busy)
            {
                st = self.shared.idle.wait(st).unwrap();
            }
            if !st.slots[idx].live || st.slots[idx].id != id {
                // A concurrent evict retired the slot while we drained.
                return Err(ServeError::Evicted);
            }
        }
        st.slots[idx].live = false;
        st.slots[idx].draining = false;
        // Unqueue the not-yet-started jobs; their tickets are failed only
        // after the scheduler lock is released (ticket locks are never
        // taken under the state lock — see the worker's completion path).
        let mut failed: Vec<Job> = Vec::with_capacity(st.slots[idx].queue.len());
        while let Some(job) = st.slots[idx].queue.pop_front() {
            st.queued -= 1;
            failed.push(job);
        }
        while st.slots[idx].busy {
            st = self.shared.idle.wait(st).unwrap();
        }
        let backend = match st.slots[idx].backend.take() {
            Some(b) => b,
            None => {
                // State is on disk: evicting a spilled adapter hands back
                // its reloaded (exact) state.
                let path = st.slots[idx].spill.take().expect("evicted slot retains state");
                match self.load_artifact(&path) {
                    Ok(b) => {
                        let _ = std::fs::remove_file(&path);
                        b
                    }
                    Err(e) => {
                        crate::warn_log!(
                            "evict {id}: reload from {} failed: {e:#}",
                            path.display()
                        );
                        // Restore the slot (spill file kept, adapter back
                        // to live+spilled) so a transient I/O failure is
                        // retryable instead of stranding the state. We
                        // held the lock continuously since live=false, so
                        // nothing observed the intermediate state (a
                        // spilled slot is never busy and its queue is
                        // empty — `failed` is empty here).
                        st.slots[idx].spill = Some(path);
                        st.slots[idx].live = true;
                        debug_assert!(failed.is_empty(), "spilled slots have empty queues");
                        return Err(ServeError::ArtifactFailed);
                    }
                }
            }
        };
        drop(st);
        let n_failed = failed.len();
        for job in failed {
            fail(&job.ticket, ServeError::Evicted);
        }
        Ok((backend, n_failed))
    }

    /// Snapshot one live adapter to `path` as a versioned artifact without
    /// evicting it (its queue is untouched; an in-flight burst is waited
    /// out first). Returns the bytes written.
    pub fn checkpoint(&self, id: AdapterId, path: &Path) -> anyhow::Result<u64> {
        let mut st = self.shared.state.lock().unwrap();
        let idx = st
            .slots
            .iter()
            .position(|s| s.live && s.id == id)
            .ok_or_else(|| anyhow::anyhow!("checkpoint: no live adapter {id}"))?;
        loop {
            if let Some(spill) = st.slots[idx].spill.clone() {
                // Already on disk in artifact form — copy verbatim. The
                // copy runs under the scheduler lock so a concurrent
                // submit's reload (which deletes the spill file) cannot
                // race it; spill files are artifact-sized (small).
                if let Some(parent) = path.parent() {
                    if !parent.as_os_str().is_empty() {
                        std::fs::create_dir_all(parent)?;
                    }
                }
                let bytes = std::fs::copy(&spill, path)?;
                return Ok(bytes);
            }
            if !st.slots[idx].busy {
                break;
            }
            st = self.shared.idle.wait(st).unwrap();
            if !st.slots[idx].live || st.slots[idx].id != id {
                anyhow::bail!("adapter {id} was evicted during checkpoint");
            }
        }
        // Borrow the state exclusively (marked busy so dispatch and evict
        // wait), serialize outside the scheduler lock, put it back.
        let backend = st.slots[idx].backend.take().expect("idle live slot holds its backend");
        st.slots[idx].busy = true;
        let label = st.slots[idx].label.clone();
        drop(st);
        let result =
            backend.to_artifact(&label, &self.backbone).and_then(|art| art.write_to(path));
        let mut st = self.shared.state.lock().unwrap();
        st.slots[idx].backend = Some(backend);
        st.slots[idx].busy = false;
        if let Ok(bytes) = &result {
            st.slots[idx].artifact_bytes = *bytes;
        }
        drop(st);
        self.shared.work.notify_all();
        self.shared.idle.notify_all();
        result
    }

    /// Register an adapter from an artifact file exported by
    /// [`ServeCore::checkpoint`] / `psoft export` — validated against this
    /// core's backbone fingerprint before anything is installed.
    pub fn restore(&self, label: &str, path: &Path) -> anyhow::Result<AdapterId> {
        let backend = self.load_artifact(path)?;
        Ok(self.register_backend(label, backend))
    }

    /// Read + validate + reconstruct an artifact on this core's backbone.
    fn load_artifact(&self, path: &Path) -> anyhow::Result<NativeBackend> {
        let art = AdapterArtifact::read_from(path)?;
        Ok(NativeBackend::from_artifact(&self.backbone, &art)?)
    }

    /// Spill the least-recently-used idle adapters until at most `budget`
    /// are resident. Best-effort: adapters that are busy, draining, or
    /// have queued work are never spilled, so the count can transiently
    /// stay above budget. No-op when `max_resident` is 0 (unlimited).
    fn spill_down_to(
        &self,
        st: &mut MutexGuard<'_, ServeState>,
        budget: usize,
        exempt: Option<usize>,
    ) {
        if self.opts.max_resident == 0 {
            return;
        }
        loop {
            let resident = st
                .slots
                .iter()
                .filter(|s| s.live && (s.backend.is_some() || s.busy))
                .count();
            if resident <= budget {
                return;
            }
            let victim = st
                .slots
                .iter()
                .enumerate()
                .filter(|(i, s)| {
                    Some(*i) != exempt
                        && s.live
                        && !s.busy
                        && !s.draining
                        && s.queue.is_empty()
                        && s.backend.as_ref().map_or(false, |b| b.artifact_exportable())
                })
                .min_by_key(|(_, s)| s.last_used)
                .map(|(i, _)| i);
            let Some(v) = victim else { return };
            if let Err(e) = self.spill_slot(st, v) {
                crate::warn_log!(
                    "resident budget: spilling {} failed ({e:#}); keeping it in memory",
                    st.slots[v].id
                );
                return;
            }
        }
    }

    /// Serialize one idle slot's state to the spill directory and drop the
    /// in-memory copy.
    fn spill_slot(
        &self,
        st: &mut MutexGuard<'_, ServeState>,
        idx: usize,
    ) -> anyhow::Result<()> {
        let backend = st.slots[idx].backend.take().expect("spill victim is resident");
        let label = st.slots[idx].label.clone();
        let path = self.spill_dir.join(format!("adapter_{}.psoftad", st.slots[idx].id.0));
        let written = backend
            .to_artifact(&label, &self.backbone)
            .and_then(|art| art.write_to(&path));
        match written {
            Ok(bytes) => {
                st.slots[idx].spill = Some(path);
                st.slots[idx].artifact_bytes = bytes;
                Ok(())
            }
            Err(e) => {
                // Keep the adapter resident rather than losing state.
                st.slots[idx].backend = Some(backend);
                Err(e)
            }
        }
    }

    /// Reload a spilled slot's state from disk (called from `submit` with
    /// the scheduler lock held), making room under the budget first.
    fn reload_slot(
        &self,
        st: &mut MutexGuard<'_, ServeState>,
        idx: usize,
    ) -> anyhow::Result<()> {
        self.spill_down_to(st, self.opts.max_resident.saturating_sub(1), Some(idx));
        let path = st.slots[idx].spill.clone().expect("reload target is spilled");
        let backend = self.load_artifact(&path)?;
        st.slots[idx].backend = Some(backend);
        st.slots[idx].spill = None;
        let _ = std::fs::remove_file(&path);
        Ok(())
    }

    /// Enqueue one request for `id`, re-arming `ticket` to receive the
    /// result. The ticket is re-armed only once the request is accepted —
    /// a failed submit leaves the ticket's previous completion intact.
    /// Zero-allocation on the warm resident path: the batch travels as an
    /// `Arc` clone and the queue is pre-sized. A submit against a
    /// **spilled** adapter transparently reloads it from disk first
    /// (spilling the LRU resident if the budget requires), so callers
    /// never observe eviction-to-disk except as latency.
    pub fn submit(
        &self,
        id: AdapterId,
        batch: &Arc<Batch>,
        kind: ReqKind,
        ticket: &Ticket,
    ) -> Result<(), ServeError> {
        let mut st = self.shared.state.lock().unwrap();
        if st.shutdown {
            return Err(ServeError::ShuttingDown);
        }
        let cap = self.opts.queue_cap.max(1);
        let idx = st
            .slots
            .iter()
            .position(|s| s.live && s.id == id)
            .ok_or(ServeError::UnknownAdapter)?;
        if st.slots[idx].draining {
            // Evict-with-drain in progress: behaves as already evicted
            // for new work.
            return Err(ServeError::Evicted);
        }
        if st.slots[idx].queue.len() >= cap {
            st.slots[idx].stats.rejected += 1;
            return Err(ServeError::QueueFull);
        }
        st.clock += 1;
        st.slots[idx].last_used = st.clock;
        if st.slots[idx].spill.is_some() {
            if let Err(e) = self.reload_slot(&mut st, idx) {
                crate::warn_log!("submit {id}: artifact reload failed: {e:#}");
                return Err(ServeError::ArtifactFailed);
            }
        } else if self.opts.max_resident != 0 {
            // Already resident: opportunistically re-enforce the budget so
            // adapters left resident by an earlier concurrent burst (no
            // idle victims at the time) spill once they quiesce. With the
            // default unlimited budget this branch is a no-op, keeping the
            // warm resident path allocation-free.
            self.spill_down_to(&mut st, self.opts.max_resident, Some(idx));
        }
        // Arm under the state lock: workers need that lock to dispatch,
        // so the job cannot complete before it is armed. (No path ever
        // holds a ticket lock and then takes the state lock, so this
        // nesting is deadlock-free.)
        ticket.arm();
        st.slots[idx].queue.push_back(Job {
            batch: Arc::clone(batch),
            kind,
            ticket: Arc::clone(&ticket.inner),
            enqueued: Instant::now(),
        });
        st.queued += 1;
        drop(st);
        self.shared.work.notify_one();
        Ok(())
    }

    /// Block until every queued and in-flight request has completed.
    /// (Unpauses dispatch if the core started paused.)
    pub fn drain(&self) {
        let mut st = self.shared.state.lock().unwrap();
        if st.paused {
            st.paused = false;
            self.shared.work.notify_all();
        }
        while st.queued > 0 || st.slots.iter().any(|s| s.busy) {
            st = self.shared.idle.wait(st).unwrap();
        }
    }

    /// Start dispatching (cores built with `start_paused`).
    pub fn resume(&self) {
        let mut st = self.shared.state.lock().unwrap();
        st.paused = false;
        drop(st);
        self.shared.work.notify_all();
    }

    /// Stats for one adapter (live or already evicted, while its slot has
    /// not been reused).
    pub fn stats(&self, id: AdapterId) -> Option<AdapterStats> {
        let st = self.shared.state.lock().unwrap();
        st.slots.iter().find(|s| s.id == id).map(|s| s.stats)
    }

    /// (id, label, stats) of every live adapter, in slot order.
    pub fn adapters(&self) -> Vec<(AdapterId, String, AdapterStats)> {
        let st = self.shared.state.lock().unwrap();
        st.slots
            .iter()
            .filter(|s| s.live)
            .map(|s| (s.id, s.label.clone(), s.stats))
            .collect()
    }

    /// Number of live adapters.
    pub fn num_adapters(&self) -> usize {
        self.shared.state.lock().unwrap().slots.iter().filter(|s| s.live).count()
    }

    /// Currently queued (undispatched) requests for one adapter.
    pub fn queue_len(&self, id: AdapterId) -> Option<usize> {
        let st = self.shared.state.lock().unwrap();
        st.slots.iter().find(|s| s.live && s.id == id).map(|s| s.queue.len())
    }

    /// Size of this adapter's artifact encoding in bytes (cached at
    /// registration, refreshed by checkpoint/spill) — the bytes-per-
    /// adapter figure reports put next to Table 8 parameter counts.
    pub fn artifact_bytes(&self, id: AdapterId) -> Option<u64> {
        let st = self.shared.state.lock().unwrap();
        st.slots.iter().find(|s| s.live && s.id == id).map(|s| s.artifact_bytes)
    }

    /// Whether the adapter's state is currently in memory (`false` ⇒
    /// spilled to disk awaiting a transparent reload).
    pub fn resident(&self, id: AdapterId) -> Option<bool> {
        let st = self.shared.state.lock().unwrap();
        st.slots
            .iter()
            .find(|s| s.live && s.id == id)
            .map(|s| s.backend.is_some() || s.busy)
    }

    /// Number of adapters whose state is resident in memory.
    pub fn num_resident(&self) -> usize {
        let st = self.shared.state.lock().unwrap();
        st.slots.iter().filter(|s| s.live && (s.backend.is_some() || s.busy)).count()
    }

    /// The directory spilled artifacts are written to.
    pub fn spill_dir(&self) -> &Path {
        &self.spill_dir
    }

    /// The recorded dispatch order (adapter id per dispatched request),
    /// up to `trace_cap` entries.
    pub fn trace(&self) -> Vec<AdapterId> {
        self.shared.state.lock().unwrap().trace.clone()
    }
}

impl Drop for ServeCore {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            st.paused = false;
        }
        self.shared.work.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Spilled artifacts are a transparent cache, not the durability
        // API (that is `checkpoint`): remove the files this core owns,
        // then the spill directory if that leaves it empty. A caller-
        // provided directory with other contents is left in place.
        let st = self.shared.state.lock().unwrap();
        for s in &st.slots {
            if let Some(p) = &s.spill {
                let _ = std::fs::remove_file(p);
            }
        }
        drop(st);
        let _ = std::fs::remove_dir(&self.spill_dir);
    }
}

fn next_runnable(st: &ServeState) -> Option<usize> {
    let n = st.slots.len();
    for k in 0..n {
        let i = (st.rr + k) % n;
        let s = &st.slots[i];
        if s.live && !s.busy && s.backend.is_some() && !s.queue.is_empty() {
            return Some(i);
        }
    }
    None
}

fn worker_loop(shared: &Shared, burst: usize) {
    let mut ws = Workspace::new();
    let mut jobs: Vec<Job> = Vec::with_capacity(burst);
    loop {
        // Dispatch: pick the next runnable slot round-robin and take up to
        // `burst` of its queued jobs plus its backend.
        let (slot_idx, mut backend) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if !st.paused {
                    if let Some(idx) = next_runnable(&st) {
                        let n = st.slots.len();
                        st.rr = (idx + 1) % n;
                        let id = st.slots[idx].id;
                        {
                            let slot = &mut st.slots[idx];
                            slot.busy = true;
                            for _ in 0..burst {
                                match slot.queue.pop_front() {
                                    Some(j) => jobs.push(j),
                                    None => break,
                                }
                            }
                        }
                        st.queued -= jobs.len();
                        // Record per entry up to the configured cap (never
                        // past `trace_cap`, so pushes never reallocate and
                        // the trace has no mid-stream gaps).
                        if st.trace.len() < st.trace_cap {
                            let room = st.trace_cap - st.trace.len();
                            for _ in 0..jobs.len().min(room) {
                                st.trace.push(id);
                            }
                        }
                        let backend =
                            st.slots[idx].backend.take().expect("runnable slot has its backend");
                        break (idx, backend);
                    }
                }
                if st.shutdown && st.queued == 0 {
                    return;
                }
                st = shared.work.wait(st).unwrap();
            }
        };

        // Service the burst outside the scheduler lock; other workers keep
        // dispatching other adapters meanwhile.
        let mut done = 0u64;
        let mut train_steps = 0u64;
        let mut service_ns = 0u64;
        let mut latency_ns = 0u64;
        let mut max_latency_ns = 0u64;
        for job in jobs.drain(..) {
            let svc = Instant::now();
            let (loss, metric) = match job.kind {
                ReqKind::Eval => {
                    native::evaluate_into(&backend.model, &job.batch, &mut backend.bufs, &mut ws)
                }
                ReqKind::Train(hyper) => {
                    train_steps += 1;
                    backend.step_core(&job.batch, &hyper, &mut ws)
                }
            };
            complete(&job.ticket, loss, metric, &backend.bufs.preds);
            done += 1;
            service_ns += svc.elapsed().as_nanos() as u64;
            let lat = job.enqueued.elapsed().as_nanos() as u64;
            latency_ns += lat;
            max_latency_ns = max_latency_ns.max(lat);
        }

        // Put the adapter state back and publish stats.
        {
            let mut st = shared.state.lock().unwrap();
            let slot = &mut st.slots[slot_idx];
            slot.backend = Some(backend);
            slot.busy = false;
            slot.stats.processed += done;
            slot.stats.train_steps += train_steps;
            slot.stats.service_ns += service_ns;
            slot.stats.total_latency_ns += latency_ns;
            slot.stats.max_latency_ns = slot.stats.max_latency_ns.max(max_latency_ns);
        }
        shared.work.notify_all();
        shared.idle.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Arch, MethodKind, ModelConfig, ModuleKind};
    use crate::model::native::Target;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            arch: Arch::Encoder,
            vocab_size: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            max_seq: 10,
            n_classes: 2,
        }
    }

    fn tiny_batch(cfg: &ModelConfig, seed: u64) -> Arc<Batch> {
        let mut rng = Rng::new(seed);
        let (bsz, seq) = (2usize, 6usize);
        let tokens: Vec<i32> = (0..bsz * seq).map(|_| rng.below(cfg.vocab_size) as i32).collect();
        let labels: Vec<usize> = (0..bsz).map(|b| (tokens[b * seq] as usize) % 2).collect();
        Arc::new(Batch {
            batch: bsz,
            seq,
            tokens,
            pad: vec![1.0; bsz * seq],
            target: Target::Class(labels),
        })
    }

    fn lora_peft() -> PeftConfig {
        PeftConfig::new(MethodKind::Lora, 3).with_modules(vec![ModuleKind::Q, ModuleKind::V])
    }

    #[test]
    fn eval_roundtrip_matches_direct_backend() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(901);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let opts = ServeOptions { workers: 2, trace_cap: 0, ..Default::default() };
        let core = ServeCore::new(Arc::clone(&bb), opts);
        let id = core.register("lora_r3", &lora_peft(), 7);

        // Direct reference: same construction path, no serving.
        let mut direct = NativeBackend::for_adapter(&bb, &lora_peft(), 7);
        let batch = tiny_batch(&cfg, 11);
        let mut ws = Workspace::new();
        let (ref_loss, ref_metric) =
            native::evaluate_into(&direct.model, &batch, &mut direct.bufs, &mut ws);

        let ticket = Ticket::new(batch.batch);
        core.submit(id, &batch, ReqKind::Eval, &ticket).unwrap();
        let (loss, metric) = ticket.wait().unwrap();
        assert_eq!(loss, ref_loss);
        assert_eq!(metric, ref_metric);
        ticket.with_preds(|p| assert_eq!(p, &direct.bufs.preds[..]));

        let stats = core.stats(id).unwrap();
        assert_eq!(stats.processed, 1);
        assert_eq!(stats.train_steps, 0);
    }

    #[test]
    fn evict_returns_state_and_fails_queued_requests() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(902);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let opts =
            ServeOptions { workers: 1, start_paused: true, queue_cap: 8, ..Default::default() };
        let core = ServeCore::new(Arc::clone(&bb), opts);
        let id = core.register("lora_r3", &lora_peft(), 7);
        let batch = tiny_batch(&cfg, 12);
        let ticket = Ticket::new(batch.batch);
        core.submit(id, &batch, ReqKind::Eval, &ticket).unwrap();

        // Paused ⇒ the job is still queued; strict evict must refuse and
        // report exactly how many requests are pending.
        assert_eq!(core.evict(id), Err(ServeError::PendingRequests(1)));

        // Explicit reject: queued requests fail, the count comes back.
        let (backend, failed) = core.evict_with(id, EvictMode::Reject).unwrap();
        assert_eq!(failed, 1);
        assert_eq!(ticket.wait(), Err(ServeError::Evicted));
        assert_eq!(core.num_adapters(), 0);
        assert!(core.submit(id, &batch, ReqKind::Eval, &ticket).is_err());

        // The evicted state is intact and can be re-registered (hot swap);
        // the slot is reused rather than grown.
        let id2 = core.register_backend("lora_r3", backend);
        assert_ne!(id, id2, "adapter ids are never reused");
        core.resume();
        core.submit(id2, &batch, ReqKind::Eval, &ticket).unwrap();
        assert!(ticket.wait().is_ok());

        // An idle adapter evicts strictly without complaint.
        core.drain();
        assert!(core.evict(id2).is_ok());
    }

    #[test]
    fn evict_drain_serves_queue_out_before_returning_state() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(905);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let opts =
            ServeOptions { workers: 1, start_paused: true, queue_cap: 8, ..Default::default() };
        let core = ServeCore::new(Arc::clone(&bb), opts);
        let id = core.register("lora_r3", &lora_peft(), 7);
        let batch = tiny_batch(&cfg, 14);
        let tickets: Vec<Ticket> = (0..3).map(|_| Ticket::new(batch.batch)).collect();
        for t in &tickets {
            core.submit(id, &batch, ReqKind::Eval, t).unwrap();
        }
        // Drain unpauses, serves all 3, then evicts with nothing failed.
        let (backend, failed) = core.evict_with(id, EvictMode::Drain).unwrap();
        assert_eq!(failed, 0);
        for t in &tickets {
            assert!(t.wait().is_ok(), "drained requests complete normally");
        }
        assert_eq!(core.num_adapters(), 0);
        assert_eq!(backend.opt.step, 0);
    }

    #[test]
    fn checkpoint_restore_roundtrip_preserves_results() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(906);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let opts = ServeOptions { workers: 1, ..Default::default() };
        let core = ServeCore::new(Arc::clone(&bb), opts);
        let id = core.register("lora_r3", &lora_peft(), 7);
        let batch = tiny_batch(&cfg, 15);
        let ticket = Ticket::new(batch.batch);
        // A couple of train steps so the checkpoint carries real state.
        for _ in 0..2 {
            core.submit(id, &batch, ReqKind::Train(Hyper::default()), &ticket).unwrap();
            ticket.wait().unwrap();
        }
        let dir = std::env::temp_dir()
            .join(format!("psoft_ckpt_test_{}", std::process::id()));
        let path = dir.join("lora_r3.psoftad");
        let bytes = core.checkpoint(id, &path).unwrap();
        assert!(bytes > 0);
        assert_eq!(core.artifact_bytes(id), Some(bytes));

        // The checkpointed adapter keeps serving...
        core.submit(id, &batch, ReqKind::Eval, &ticket).unwrap();
        let (loss_orig, _) = ticket.wait().unwrap();

        // ...and its restored twin answers bit-identically.
        let id2 = core.restore("lora_r3_restored", &path).unwrap();
        core.submit(id2, &batch, ReqKind::Eval, &ticket).unwrap();
        let (loss_restored, _) = ticket.wait().unwrap();
        assert_eq!(loss_orig, loss_restored, "restore must be bit-exact");
        let be = core.evict(id2).unwrap();
        assert_eq!(be.opt.step, 2, "optimizer step count survives the round-trip");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn queue_cap_rejects_and_counts() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(903);
        let bb = Arc::new(Backbone::random(&cfg, &mut rng));
        let opts =
            ServeOptions { workers: 1, start_paused: true, queue_cap: 3, ..Default::default() };
        let core = ServeCore::new(bb, opts);
        let id = core.register("lora_r3", &lora_peft(), 7);
        let batch = tiny_batch(&cfg, 13);
        let tickets: Vec<Ticket> = (0..4).map(|_| Ticket::new(batch.batch)).collect();
        for t in &tickets[..3] {
            core.submit(id, &batch, ReqKind::Eval, t).unwrap();
        }
        assert_eq!(core.queue_len(id), Some(3));
        assert_eq!(
            core.submit(id, &batch, ReqKind::Eval, &tickets[3]),
            Err(ServeError::QueueFull)
        );
        assert_eq!(core.stats(id).unwrap().rejected, 1);
        core.drain();
        for t in &tickets[..3] {
            assert!(t.wait().is_ok());
        }
    }
}
