//! PJRT backend: load AOT artifacts (HLO text) and run the fused
//! train/eval steps on the XLA CPU client.
//!
//! This is the production path of the three-layer architecture: Python
//! lowered the model once (`make artifacts`); here Rust compiles the HLO,
//! owns the parameter/optimizer/frozen buffers, and streams batches. No
//! Python anywhere at runtime.
//!
//! The XLA execution path requires the external `xla` crate, which the
//! offline build environment does not provide; it is gated behind the
//! `xla` cargo feature (enabling it also requires adding the dependency).
//! Without the feature this module compiles a stub whose constructors
//! return an error, so the CLI and trainer still build and the native
//! backend is unaffected. [`ArtifactMeta`] (pure JSON) is always
//! available for `psoft inspect`.

use super::{Backend, Hyper};
use crate::linalg::Workspace;
use crate::model::native::{Batch, StepOutput};
use crate::model::NativeModel;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Parsed `<name>.meta.json`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub trainable_size: usize,
    pub frozen_size: usize,
    pub batch: usize,
    pub seq: usize,
    /// "i32" for encoder classification targets, "f32" otherwise.
    pub target_dtype: String,
    pub arch: String,
}

impl ArtifactMeta {
    pub fn load(dir: &Path, name: &str) -> Result<ArtifactMeta> {
        let path = dir.join(format!("{name}.meta.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Ok(ArtifactMeta {
            name: name.to_string(),
            trainable_size: j.get("trainable_size").expect_usize("trainable_size")?,
            frozen_size: j.get("frozen_size").expect_usize("frozen_size")?,
            batch: j.get("batch").expect_usize("batch")?,
            seq: j.get("seq").expect_usize("seq")?,
            target_dtype: j.get("target_dtype").as_str().unwrap_or("i32").to_string(),
            arch: j.get("spec").get("arch").as_str().unwrap_or("encoder").to_string(),
        })
    }
}

#[cfg(feature = "xla")]
mod backend_impl {
    use super::*;
    use crate::model::native::Target;
    use std::path::PathBuf;

    /// A compiled artifact pair (train + eval executables).
    pub struct PjrtBackend {
        meta: ArtifactMeta,
        client: xla::PjRtClient,
        train_exe: xla::PjRtLoadedExecutable,
        eval_exe: xla::PjRtLoadedExecutable,
        /// State buffers owned by Rust.
        trainable: Vec<f32>,
        m: Vec<f32>,
        v: Vec<f32>,
        step: usize,
        frozen: Vec<f32>,
    }

    impl PjrtBackend {
        /// Load + compile an artifact, initializing state from a Rust-side
        /// model (which owns initialization: SVD splits, Cayley identity, …).
        pub fn from_artifact(dir: &Path, name: &str, model: &NativeModel) -> Result<PjrtBackend> {
            let meta = ArtifactMeta::load(dir, name)?;
            let trainable = model.trainable_flat();
            let frozen = model.frozen_flat();
            if trainable.len() != meta.trainable_size {
                bail!(
                    "trainable size mismatch: model {} vs artifact {} — model/peft config must match the manifest entry",
                    trainable.len(),
                    meta.trainable_size
                );
            }
            if frozen.len() != meta.frozen_size {
                bail!(
                    "frozen size mismatch: model {} vs artifact {}",
                    frozen.len(),
                    meta.frozen_size
                );
            }
            Self::with_state(dir, meta, trainable, frozen)
        }

        /// Load with explicit state vectors (fixture replay, checkpoints).
        pub fn with_state(
            dir: &Path,
            meta: ArtifactMeta,
            trainable: Vec<f32>,
            frozen: Vec<f32>,
        ) -> Result<PjrtBackend> {
            let client = xla::PjRtClient::cpu()?;
            let load = |suffix: &str| -> Result<xla::PjRtLoadedExecutable> {
                let path: PathBuf = dir.join(format!("{}.{suffix}.hlo.txt", meta.name));
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                client
                    .compile(&comp)
                    .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))
            };
            let train_exe = load("train")?;
            let eval_exe = load("eval")?;
            let p = trainable.len();
            Ok(PjrtBackend {
                meta,
                client,
                train_exe,
                eval_exe,
                trainable,
                m: vec![0.0; p],
                v: vec![0.0; p],
                step: 0,
                frozen,
            })
        }

        pub fn meta(&self) -> &ArtifactMeta {
            &self.meta
        }

        fn check_batch(&self, batch: &Batch) -> Result<()> {
            if batch.batch != self.meta.batch || batch.seq != self.meta.seq {
                bail!(
                    "batch shape ({}, {}) does not match artifact ({}, {})",
                    batch.batch,
                    batch.seq,
                    self.meta.batch,
                    self.meta.seq
                );
            }
            Ok(())
        }

        fn batch_literals(
            &self,
            batch: &Batch,
        ) -> Result<(xla::Literal, xla::Literal, xla::Literal)> {
            let b = batch.batch as i64;
            let s = batch.seq as i64;
            let tokens = xla::Literal::vec1(&batch.tokens).reshape(&[b, s])?;
            let target = match &batch.target {
                Target::Class(labels) => {
                    let l: Vec<i32> = labels.iter().map(|&x| x as i32).collect();
                    xla::Literal::vec1(&l)
                }
                Target::Reg(vals) => xla::Literal::vec1(&vals[..]),
                Target::LmMask(mask) => xla::Literal::vec1(&mask[..]).reshape(&[b, s])?,
            };
            let pad = xla::Literal::vec1(&batch.pad[..]).reshape(&[b, s])?;
            Ok((tokens, target, pad))
        }
    }

    impl Backend for PjrtBackend {
        fn train_step(
            &mut self,
            batch: &Batch,
            hyper: &Hyper,
            _ws: &mut Workspace,
        ) -> Result<StepOutput> {
            self.check_batch(batch)?;
            self.step += 1;
            let (tokens, target, pad) = self.batch_literals(batch)?;
            let trainable = xla::Literal::vec1(&self.trainable[..]);
            let m = xla::Literal::vec1(&self.m[..]);
            let v = xla::Literal::vec1(&self.v[..]);
            let step = xla::Literal::vec1(&[self.step as f32]);
            let hyper_l = xla::Literal::vec1(&[
                hyper.lr as f32,
                hyper.head_lr as f32,
                hyper.weight_decay as f32,
                hyper.gamma_orth as f32,
            ]);
            let frozen = xla::Literal::vec1(&self.frozen[..]);
            let inputs = [trainable, m, v, step, hyper_l, tokens, target, pad, frozen];
            let result =
                self.train_exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
            let parts = result.to_tuple()?;
            if parts.len() != 5 {
                bail!("train artifact returned {} outputs, expected 5", parts.len());
            }
            let mut it = parts.into_iter();
            self.trainable = it.next().unwrap().to_vec::<f32>()?;
            self.m = it.next().unwrap().to_vec::<f32>()?;
            self.v = it.next().unwrap().to_vec::<f32>()?;
            let loss = it.next().unwrap().to_vec::<f32>()?[0] as f64;
            let metric = it.next().unwrap().to_vec::<f32>()?[0] as f64;
            Ok(StepOutput { loss, metric, preds: Vec::new() })
        }

        fn evaluate(&mut self, batch: &Batch, _ws: &mut Workspace) -> Result<StepOutput> {
            self.check_batch(batch)?;
            let (tokens, target, pad) = self.batch_literals(batch)?;
            let trainable = xla::Literal::vec1(&self.trainable[..]);
            let frozen = xla::Literal::vec1(&self.frozen[..]);
            let result = self
                .eval_exe
                .execute::<xla::Literal>(&[trainable, frozen, tokens, target, pad])?[0][0]
                .to_literal_sync()?;
            let parts = result.to_tuple()?;
            if parts.len() != 3 {
                bail!("eval artifact returned {} outputs, expected 3", parts.len());
            }
            let mut it = parts.into_iter();
            let loss = it.next().unwrap().to_vec::<f32>()?[0] as f64;
            let metric = it.next().unwrap().to_vec::<f32>()?[0] as f64;
            let preds = it.next().unwrap().to_vec::<f32>()?;
            Ok(StepOutput { loss, metric, preds })
        }

        fn trainable(&self) -> Vec<f32> {
            self.trainable.clone()
        }

        fn set_trainable(&mut self, p: &[f32]) -> Result<()> {
            if p.len() != self.trainable.len() {
                bail!("trainable length {} vs {}", p.len(), self.trainable.len());
            }
            self.trainable.copy_from_slice(p);
            Ok(())
        }

        fn num_trainable(&self) -> usize {
            self.trainable.len()
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }

        fn steps(&self) -> usize {
            self.step
        }
    }

    /// Mark unused field as intentionally held (client must outlive
    /// executables).
    impl Drop for PjrtBackend {
        fn drop(&mut self) {
            let _ = &self.client;
        }
    }
}

#[cfg(not(feature = "xla"))]
mod backend_impl {
    use super::*;

    /// Stub PJRT backend for builds without the `xla` feature. The type is
    /// uninhabited: constructors always return an error, so every method
    /// body is statically unreachable.
    pub struct PjrtBackend {
        never: std::convert::Infallible,
    }

    impl PjrtBackend {
        pub fn from_artifact(
            _dir: &Path,
            _name: &str,
            _model: &NativeModel,
        ) -> Result<PjrtBackend> {
            bail!(
                "this binary was built without the `xla` feature — the PJRT backend is \
                 unavailable (use --backend native, or rebuild with --features xla and the \
                 xla dependency)"
            )
        }

        pub fn with_state(
            _dir: &Path,
            _meta: ArtifactMeta,
            _trainable: Vec<f32>,
            _frozen: Vec<f32>,
        ) -> Result<PjrtBackend> {
            bail!("this binary was built without the `xla` feature — the PJRT backend is unavailable")
        }

        pub fn meta(&self) -> &ArtifactMeta {
            match self.never {}
        }
    }

    impl Backend for PjrtBackend {
        fn train_step(
            &mut self,
            _batch: &Batch,
            _hyper: &Hyper,
            _ws: &mut Workspace,
        ) -> Result<StepOutput> {
            match self.never {}
        }

        fn evaluate(&mut self, _batch: &Batch, _ws: &mut Workspace) -> Result<StepOutput> {
            match self.never {}
        }

        fn trainable(&self) -> Vec<f32> {
            match self.never {}
        }

        fn set_trainable(&mut self, _p: &[f32]) -> Result<()> {
            match self.never {}
        }

        fn num_trainable(&self) -> usize {
            match self.never {}
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }

        fn steps(&self) -> usize {
            match self.never {}
        }
    }
}

pub use backend_impl::PjrtBackend;
