//! Open-loop trace-driven load generation for fleet-scale serve
//! benchmarks (`benches/slo.rs`).
//!
//! Closed-loop load (submit, wait, submit) hides scheduling pathologies:
//! the client slows down exactly when the server does, so queues never
//! build. Production traffic is **open-loop** — arrivals keep coming at
//! the offered rate whether or not the fleet keeps up — and skewed:
//!
//! - **Poisson arrivals**: exponential inter-arrival gaps at a
//!   configured aggregate rate ([`LoadSpec::rate_rps`]).
//! - **Zipf adapter popularity**: request `k`-th most popular adapter
//!   with probability ∝ 1/k^s ([`LoadSpec::zipf_s`]) — a few hot
//!   adapters, a long cold tail fighting for `max_resident` slots.
//! - **Heavy-tailed lengths**: prompt and output lengths drawn from a
//!   bounded Pareto ([`LengthDist`]) — most requests short, a fat tail
//!   of long ones.
//! - **Tiered traffic**: a configurable share of requests is tagged
//!   interactive (tier 0, optionally deadline-bearing); the rest is
//!   batch (tier 1).
//!
//! Everything is generated **deterministically** from
//! [`LoadSpec::seed`] via the repo's split-stream [`Rng`], so a trace
//! is reproducible across runs and machines; the replay loop in the
//! bench owns the wall clock.

use crate::util::rng::Rng;
use std::time::Duration;

/// Bounded-Pareto length distribution over `[min, max]` with tail
/// exponent `alpha` (smaller ⇒ heavier tail). `alpha <= 0` degenerates
/// to uniform over the range.
#[derive(Clone, Copy, Debug)]
pub struct LengthDist {
    pub min: usize,
    pub max: usize,
    pub alpha: f64,
}

impl LengthDist {
    pub fn new(min: usize, max: usize, alpha: f64) -> LengthDist {
        LengthDist { min, max, alpha }
    }

    /// Draw one length. Inverse-CDF of the bounded Pareto: for u in
    /// (0, 1), x = (l^-a - u (l^-a - h^-a))^(-1/a) over [l, h].
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let lo = self.min.max(1) as f64;
        let hi = (self.max.max(self.min)).max(1) as f64;
        if hi <= lo {
            return self.min.max(1);
        }
        let u = rng.f64();
        let x = if self.alpha > 0.0 {
            let la = lo.powf(-self.alpha);
            let ha = hi.powf(-self.alpha);
            (la - u * (la - ha)).powf(-1.0 / self.alpha)
        } else {
            lo + u * (hi - lo)
        };
        (x.floor() as usize).clamp(self.min.max(1), self.max.max(self.min))
    }
}

/// Full description of one synthetic open-loop workload.
#[derive(Clone, Debug)]
pub struct LoadSpec {
    /// Fleet size: arrivals target adapter indices `0..adapters`
    /// (rank 0 = most popular).
    pub adapters: usize,
    /// Aggregate offered load, requests per second.
    pub rate_rps: f64,
    /// Trace length in requests.
    pub n_requests: usize,
    /// Zipf popularity exponent (0 = uniform; ~1 = classic web skew).
    pub zipf_s: f64,
    /// Prompt-length distribution (tokens).
    pub prompt_len: LengthDist,
    /// Output-length distribution (max_new_tokens).
    pub output_len: LengthDist,
    /// Fraction of requests tagged interactive (tier 0); the remainder
    /// is batch traffic (tier 1).
    pub interactive_share: f64,
    /// Master seed: the whole trace is a pure function of the spec.
    pub seed: u64,
}

/// One synthetic arrival: when, which adapter, what shape, which tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Arrival {
    /// Offset from trace start (open-loop: the replay clock, not the
    /// completion of any earlier request, decides when this fires).
    pub at: Duration,
    /// Popularity-ranked adapter index in `0..spec.adapters`.
    pub adapter: usize,
    /// Prompt length in tokens.
    pub prompt_len: usize,
    /// Decode budget (max_new_tokens).
    pub max_new_tokens: usize,
    /// Scheduling tier: 0 = interactive, 1 = batch.
    pub tier: usize,
}

/// A fully materialized arrival trace, sorted by arrival time by
/// construction.
#[derive(Clone, Debug)]
pub struct Trace {
    pub arrivals: Vec<Arrival>,
}

impl Trace {
    /// Generate the deterministic trace for `spec`. Independent RNG
    /// streams per aspect (timing / popularity / shapes / tiering), so
    /// e.g. changing the length distribution never perturbs arrival
    /// times.
    pub fn generate(spec: &LoadSpec) -> Trace {
        let mut master = Rng::new(spec.seed ^ 0x6c6f_6164_6765_6e21);
        let mut t_rng = master.child(1);
        let mut a_rng = master.child(2);
        let mut s_rng = master.child(3);
        let mut c_rng = master.child(4);

        // Zipf CDF over ranks 1..=n: cum[k] = Σ_{j<=k} 1/j^s, normalized.
        let n = spec.adapters.max(1);
        let mut cum = Vec::with_capacity(n);
        let mut total = 0.0f64;
        for k in 1..=n {
            total += 1.0 / (k as f64).powf(spec.zipf_s.max(0.0));
            cum.push(total);
        }
        for c in cum.iter_mut() {
            *c /= total;
        }

        let rate = spec.rate_rps.max(1e-9);
        let mut now_s = 0.0f64;
        let mut arrivals = Vec::with_capacity(spec.n_requests);
        for _ in 0..spec.n_requests {
            // Exponential inter-arrival gap: -ln(1-u)/rate, u in [0,1).
            let u = t_rng.f64().min(1.0 - 1e-12);
            now_s += -(1.0 - u).ln() / rate;
            // Zipf rank via binary search on the cumulative weights.
            let p = a_rng.f64();
            let adapter = cum.partition_point(|&c| c < p).min(n - 1);
            let prompt_len = spec.prompt_len.sample(&mut s_rng);
            let max_new_tokens = spec.output_len.sample(&mut s_rng);
            let tier = if c_rng.f64() < spec.interactive_share { 0 } else { 1 };
            arrivals.push(Arrival {
                at: Duration::from_secs_f64(now_s),
                adapter,
                prompt_len,
                max_new_tokens,
                tier,
            });
        }
        Trace { arrivals }
    }

    /// Total span of the trace (arrival time of the last request).
    pub fn span(&self) -> Duration {
        self.arrivals.last().map_or(Duration::ZERO, |a| a.at)
    }

    /// Offered load of the materialized trace in requests/second.
    pub fn offered_rps(&self) -> f64 {
        let span = self.span().as_secs_f64();
        if span <= 0.0 {
            0.0
        } else {
            self.arrivals.len() as f64 / span
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> LoadSpec {
        LoadSpec {
            adapters: 50,
            rate_rps: 100.0,
            n_requests: 5_000,
            zipf_s: 1.0,
            prompt_len: LengthDist::new(2, 8, 1.2),
            output_len: LengthDist::new(1, 6, 1.2),
            interactive_share: 0.5,
            seed: 42,
        }
    }

    #[test]
    fn trace_is_deterministic_in_the_seed() {
        let a = Trace::generate(&spec());
        let b = Trace::generate(&spec());
        assert_eq!(a.arrivals, b.arrivals);
        let mut other = spec();
        other.seed = 43;
        let c = Trace::generate(&other);
        assert_ne!(a.arrivals, c.arrivals);
    }

    #[test]
    fn arrivals_are_sorted_and_mean_gap_matches_rate() {
        let t = Trace::generate(&spec());
        assert_eq!(t.arrivals.len(), 5_000);
        for w in t.arrivals.windows(2) {
            assert!(w[0].at <= w[1].at, "arrival times are non-decreasing");
        }
        // Mean inter-arrival of Exp(rate) is 1/rate; with 5k samples the
        // sample mean lands within ±10%.
        let mean_gap = t.span().as_secs_f64() / (t.arrivals.len() - 1) as f64;
        let expect = 1.0 / 100.0;
        assert!(
            (mean_gap - expect).abs() < expect * 0.1,
            "mean gap {mean_gap:.6}s vs expected {expect:.6}s"
        );
        // And the derived offered rate agrees.
        assert!((t.offered_rps() - 100.0).abs() < 12.0);
    }

    #[test]
    fn zipf_popularity_is_head_heavy() {
        let t = Trace::generate(&spec());
        let mut counts = vec![0usize; 50];
        for a in &t.arrivals {
            assert!(a.adapter < 50);
            counts[a.adapter] += 1;
        }
        // Rank 0 beats the tail decisively and every adapter id is legal.
        let tail_mean = counts[25..].iter().sum::<usize>() as f64 / 25.0;
        assert!(
            counts[0] as f64 > 5.0 * tail_mean,
            "rank-0 count {} vs tail mean {tail_mean:.1}",
            counts[0]
        );
        // With s=1 over 50 adapters, rank 0 holds ~22% of traffic.
        let p0 = counts[0] as f64 / t.arrivals.len() as f64;
        assert!((0.15..0.30).contains(&p0), "rank-0 share {p0:.3}");
    }

    #[test]
    fn lengths_respect_bounds_and_skew_short() {
        let t = Trace::generate(&spec());
        let mut longest = 0usize;
        let mut sum = 0usize;
        for a in &t.arrivals {
            assert!((2..=8).contains(&a.prompt_len));
            assert!((1..=6).contains(&a.max_new_tokens));
            longest = longest.max(a.prompt_len);
            sum += a.prompt_len;
        }
        let mean = sum as f64 / t.arrivals.len() as f64;
        // Heavy tail: the mean sits well below the midpoint, but the max
        // still reaches the bound.
        assert!(mean < 5.0, "bounded-Pareto mean {mean:.2} should skew short");
        assert_eq!(longest, 8, "tail reaches the upper bound");
    }

    #[test]
    fn tiers_split_roughly_by_share() {
        let t = Trace::generate(&spec());
        let interactive = t.arrivals.iter().filter(|a| a.tier == 0).count();
        let share = interactive as f64 / t.arrivals.len() as f64;
        assert!((share - 0.5).abs() < 0.05, "interactive share {share:.3}");
        assert!(t.arrivals.iter().all(|a| a.tier <= 1));
    }
}
