//! Geometric analysis of weight matrices — the paper's "semantic
//! representation" machinery.
//!
//! Semantic representations are operationalized as the pairwise angles and
//! norms among weight columns (§1). This module computes those quantities,
//! verifies Theorem 4.1 (`RᵀGR = G` ⟺ angle+norm preservation) on concrete
//! matrices, computes hyperspherical energy (Liu et al. 2021), and exports
//! the angle heatmaps of Figs 9/10.

use crate::linalg::{matmul, matmul_tn, DMat, Mat};

/// Pairwise-angle matrix (radians) among the first `k` columns.
pub fn pairwise_angles(w: &Mat, k: usize) -> DMat {
    let k = k.min(w.cols);
    let cols: Vec<Vec<f64>> =
        (0..k).map(|j| (0..w.rows).map(|i| w[(i, j)] as f64).collect()).collect();
    let norms: Vec<f64> =
        cols.iter().map(|c| c.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-300)).collect();
    DMat::from_fn(k, k, |i, j| {
        if i == j {
            return 0.0;
        }
        let dot: f64 = cols[i].iter().zip(&cols[j]).map(|(&a, &b)| a * b).sum();
        (dot / (norms[i] * norms[j])).clamp(-1.0, 1.0).acos()
    })
}

/// Column norms.
pub fn column_norms(w: &Mat) -> Vec<f64> {
    w.col_norms()
}

/// Maximum deviation between two matrices' column geometries:
/// (max |Δangle| over pairs among the first k columns, max relative |Δnorm|).
pub fn geometry_deviation(w0: &Mat, w1: &Mat, k: usize) -> (f64, f64) {
    assert_eq!(w0.shape(), w1.shape());
    let a0 = pairwise_angles(w0, k);
    let a1 = pairwise_angles(w1, k);
    let mut d_angle = 0.0f64;
    for i in 0..a0.rows {
        for j in 0..a0.cols {
            d_angle = d_angle.max((a0[(i, j)] - a1[(i, j)]).abs());
        }
    }
    let mut d_norm = 0.0f64;
    for j in 0..w0.cols {
        let n0 = w0.col_norm(j).max(1e-300);
        d_norm = d_norm.max((w0.col_norm(j) - w1.col_norm(j)).abs() / n0);
    }
    (d_angle, d_norm)
}

/// Theorem 4.1 residual: ‖RᵀGR − G‖_F / ‖G‖_F with G = AᵀA.
/// Zero ⟺ the transform is a symmetry of the principal-subspace geometry.
pub fn gram_condition_residual(a: &Mat, r: &Mat) -> f64 {
    let ad: DMat = a.cast();
    let rd: DMat = r.cast();
    let g = matmul_tn(&ad, &ad);
    let rg = matmul(&matmul(&rd.transpose(), &g), &rd);
    rg.dist(&g) / g.frobenius_norm().max(1e-300)
}

/// Hyperspherical energy (Liu et al. 2021): Σ_{i≠j} ‖ŵ_i − ŵ_j‖⁻¹ over the
/// first k unit-normalized columns — the quantity OFT preserves.
pub fn hyperspherical_energy(w: &Mat, k: usize) -> f64 {
    let k = k.min(w.cols);
    let units: Vec<Vec<f64>> = (0..k)
        .map(|j| {
            let n = w.col_norm(j).max(1e-300);
            (0..w.rows).map(|i| w[(i, j)] as f64 / n).collect()
        })
        .collect();
    let mut e = 0.0;
    for i in 0..k {
        for j in 0..k {
            if i == j {
                continue;
            }
            let dist2: f64 =
                units[i].iter().zip(&units[j]).map(|(&a, &b)| (a - b) * (a - b)).sum();
            e += 1.0 / dist2.sqrt().max(1e-9);
        }
    }
    e
}

/// CSV export of an angle heatmap (degrees) — the Fig 9/10 artifacts.
pub fn angles_to_csv(angles: &DMat) -> String {
    let mut out = String::new();
    for i in 0..angles.rows {
        let row: Vec<String> =
            (0..angles.cols).map(|j| format!("{:.3}", angles[(i, j)].to_degrees())).collect();
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{cayley_exact, skew_from_params, skew_param_count};
    use crate::util::check::{ensure, forall};
    use crate::util::rng::Rng;

    #[test]
    fn angles_of_orthogonal_columns() {
        let w = Mat::eye(4);
        let a = pairwise_angles(&w, 4);
        for i in 0..4 {
            for j in 0..4 {
                let expect = if i == j { 0.0 } else { std::f64::consts::FRAC_PI_2 };
                assert!((a[(i, j)] - expect).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn theorem_4_1_sufficiency_property() {
        // For orthonormal A and orthogonal R: RᵀGR = G (G = I), and the
        // transformed matrix A·R·B preserves B-column geometry through A.
        forall(
            161,
            15,
            |rng| {
                let d = 8 + rng.below(8);
                let r = 2 + rng.below(4);
                let n = 4 + rng.below(6);
                let a_rand = DMat::randn(d, r, 1.0, rng);
                let a: Mat = crate::linalg::orthonormal_columns(&a_rand).cast();
                let params: Vec<f64> =
                    (0..skew_param_count(r)).map(|_| rng.normal() * 0.5).collect();
                let rot: Mat = cayley_exact(&skew_from_params(r, &params)).cast();
                let b = Mat::randn(r, n, 1.0, rng);
                (a, rot, b)
            },
            |(a, rot, b)| {
                ensure(
                    gram_condition_residual(a, rot) < 1e-5,
                    format!("Gram residual {}", gram_condition_residual(a, rot)),
                )?;
                let w_pri = matmul(a, b);
                let w_tuned = matmul(&matmul(a, rot), b);
                let (d_angle, d_norm) = geometry_deviation(&w_pri, &w_tuned, b.cols);
                ensure(d_angle < 1e-4, format!("angle deviation {d_angle}"))?;
                ensure(d_norm < 1e-4, format!("norm deviation {d_norm}"))
            },
        );
    }

    #[test]
    fn theorem_4_1_necessity_violated_by_nonisometry() {
        // A non-orthogonal R (anisotropic scaling) breaks the Gram condition
        // AND the geometry — the necessity direction of the theorem.
        let mut rng = Rng::new(162);
        let a_rand = DMat::randn(10, 3, 1.0, &mut rng);
        let a: Mat = crate::linalg::orthonormal_columns(&a_rand).cast();
        let mut r = Mat::eye(3);
        r[(0, 0)] = 2.0;
        let b = Mat::randn(3, 6, 1.0, &mut rng);
        assert!(gram_condition_residual(&a, &r) > 0.1);
        let w_pri = matmul(&a, &b);
        let w_tuned = matmul(&matmul(&a, &r), &b);
        let (d_angle, d_norm) = geometry_deviation(&w_pri, &w_tuned, 6);
        assert!(d_angle > 1e-3 || d_norm > 1e-3, "geometry should move: {d_angle} {d_norm}");
    }

    #[test]
    fn uniform_scaling_preserves_angles_not_norms() {
        // §4.3 special case: diag(α) = λI preserves angles, scales norms.
        let mut rng = Rng::new(163);
        let w = Mat::randn(8, 5, 1.0, &mut rng);
        let scaled = w.scale(1.7);
        let (d_angle, d_norm) = geometry_deviation(&w, &scaled, 5);
        assert!(d_angle < 1e-5, "{d_angle}");
        assert!((d_norm - 0.7).abs() < 1e-4, "{d_norm}");
    }

    #[test]
    fn hyperspherical_energy_invariant_under_rotation() {
        let mut rng = Rng::new(164);
        let w = Mat::randn(12, 6, 1.0, &mut rng);
        let params: Vec<f64> = (0..skew_param_count(12)).map(|_| rng.normal() * 0.4).collect();
        let rot: Mat = cayley_exact(&skew_from_params(12, &params)).cast();
        let w_rot = matmul(&rot.transpose(), &w); // rotate the row space
        let e0 = hyperspherical_energy(&w, 6);
        let e1 = hyperspherical_energy(&w_rot, 6);
        assert!((e0 - e1).abs() < 1e-4 * e0, "{e0} vs {e1}");
    }

    #[test]
    fn csv_export_shape() {
        let mut rng = Rng::new(165);
        let w = Mat::randn(6, 4, 1.0, &mut rng);
        let csv = angles_to_csv(&pairwise_angles(&w, 4));
        assert_eq!(csv.lines().count(), 4);
        assert_eq!(csv.lines().next().unwrap().split(',').count(), 4);
    }
}
