//! Descriptive statistics + timing helpers shared by the trainer, the
//! coordinator's report tables, and the benchmark harness.

use std::time::{Duration, Instant};

/// Summary statistics over a sample of f64s.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 0.5),
            p95: percentile_sorted(&sorted, 0.95),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Pearson correlation coefficient (the STS-B-style metric).
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if x.is_empty() {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (a, b) in x.iter().zip(y) {
        sxy += (a - mx) * (b - my);
        sxx += (a - mx) * (a - mx);
        syy += (b - my) * (b - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

/// Matthews correlation coefficient for binary labels (the CoLA metric).
pub fn matthews_corr(pred: &[usize], gold: &[usize]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    let (mut tp, mut tn, mut fp, mut fal_n) = (0f64, 0f64, 0f64, 0f64);
    for (&p, &g) in pred.iter().zip(gold) {
        match (p, g) {
            (1, 1) => tp += 1.0,
            (0, 0) => tn += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fal_n += 1.0,
            _ => panic!("matthews_corr expects binary labels"),
        }
    }
    let denom = ((tp + fp) * (tp + fal_n) * (tn + fp) * (tn + fal_n)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fal_n) / denom
    }
}

/// Classification accuracy.
pub fn accuracy(pred: &[usize], gold: &[usize]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hit = pred.iter().zip(gold).filter(|(p, g)| p == g).count();
    hit as f64 / pred.len() as f64
}

/// Wall-clock stopwatch with lap support.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Format a byte count in human units (memory-table output).
pub fn human_bytes(bytes: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{v:.0} {}", UNITS[u])
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a duration like "1h31m" / "57m" / "12.3s" (paper Fig 4b style).
pub fn human_duration(secs: f64) -> String {
    if secs >= 3600.0 {
        format!("{}h{:02}m", (secs / 3600.0) as u64, ((secs % 3600.0) / 60.0) as u64)
    } else if secs >= 60.0 {
        format!("{}m{:02}s", (secs / 60.0) as u64, (secs % 60.0) as u64)
    } else {
        format!("{secs:.2}s")
    }
}

/// Number of buckets in a [`QuantileSketch`] histogram. 8 exact buckets
/// for values 0..=7 plus 4 log-spaced sub-buckets per power of two up to
/// `u64::MAX`, so any recorded value lands in a bucket whose width is at
/// most 25% of its lower edge (≤ 12.5% relative error at the midpoint).
pub const SKETCH_BUCKETS: usize = 256;

/// Fixed-footprint streaming quantile estimator for latency samples.
///
/// A log-bucketed histogram: `record` is one array increment (no heap
/// allocation, no branching beyond the bucket computation), so it is safe
/// on the serve scheduler's zero-alloc warm path. `quantile` walks the
/// cumulative counts and returns the geometric midpoint of the bucket
/// containing the requested rank — within ~12.5% relative error for any
/// distribution, which is plenty for p50/p95/p99 SLO reporting.
///
/// Values are plain `u64`s; the serve layer records nanoseconds.
#[derive(Clone, Copy, Debug)]
pub struct QuantileSketch {
    counts: [u32; SKETCH_BUCKETS],
    total: u64,
}

impl Default for QuantileSketch {
    fn default() -> Self {
        QuantileSketch { counts: [0u32; SKETCH_BUCKETS], total: 0 }
    }
}

/// Bucket index for a value: exact for 0..=7, then 4 sub-buckets per
/// octave keyed off the top two bits below the MSB.
#[inline]
fn sketch_bucket(v: u64) -> usize {
    if v < 8 {
        v as usize
    } else {
        let octave = 63 - v.leading_zeros() as usize; // >= 3
        let sub = ((v >> (octave - 2)) & 3) as usize;
        8 + (octave - 3) * 4 + sub
    }
}

/// Representative value (midpoint) of a bucket index.
#[inline]
fn sketch_value(idx: usize) -> f64 {
    if idx < 8 {
        idx as f64
    } else {
        let octave = 3 + (idx - 8) / 4;
        let sub = (idx - 8) % 4;
        let lo = ((4 + sub) as u64) << (octave - 2);
        let hi = ((5 + sub) as u64) << (octave - 2);
        (lo as f64 + hi as f64) / 2.0
    }
}

impl QuantileSketch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample. Saturates per-bucket at `u32::MAX`.
    #[inline]
    pub fn record(&mut self, v: u64) {
        let b = sketch_bucket(v);
        self.counts[b] = self.counts[b].saturating_add(1);
        self.total += 1;
    }

    /// Number of samples recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Estimate the q-quantile (q in [0, 1]); 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            cum += c as u64;
            if cum >= rank {
                return sketch_value(idx);
            }
        }
        sketch_value(SKETCH_BUCKETS - 1)
    }

    /// Fold another sketch's samples into this one (bench aggregation
    /// across adapters).
    pub fn merge(&mut self, other: &QuantileSketch) {
        for (c, o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c = c.saturating_add(*o);
        }
        self.total += other.total;
    }
}

/// Current resident set size in bytes, from `/proc/self/status` (Linux).
/// Returns `None` where unavailable; callers treat that as "unchecked".
pub fn resident_set_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kib: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kib * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn pearson_perfect() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn matthews_known_value() {
        // Perfect prediction -> 1.0; inverted -> -1.0.
        let gold = [0, 1, 0, 1, 1, 0];
        assert!((matthews_corr(&gold, &gold) - 1.0).abs() < 1e-12);
        let inv: Vec<usize> = gold.iter().map(|&g| 1 - g).collect();
        assert!((matthews_corr(&inv, &gold) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn accuracy_half() {
        assert!((accuracy(&[0, 1, 0, 1], &[0, 1, 1, 0]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn humanize() {
        assert_eq!(human_bytes(512.0), "512 B");
        assert_eq!(human_bytes(4.5 * 1024.0 * 1024.0 * 1024.0), "4.50 GiB");
        assert_eq!(human_duration(5460.0), "1h31m");
        assert_eq!(human_duration(93.0), "1m33s");
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&xs, 0.95) - 95.0).abs() < 1e-9);
        assert!((percentile_sorted(&xs, 0.0) - 0.0).abs() < 1e-9);
        assert!((percentile_sorted(&xs, 1.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn sketch_small_values_are_exact() {
        let mut s = QuantileSketch::new();
        for v in [0u64, 1, 2, 3, 4, 5, 6, 7] {
            s.record(v);
        }
        assert_eq!(s.count(), 8);
        assert!((s.quantile(0.0) - 0.0).abs() < 1e-12);
        assert!((s.quantile(1.0) - 7.0).abs() < 1e-12);
        // rank ceil(0.5*8)=4 -> fourth smallest = 3
        assert!((s.quantile(0.5) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn sketch_quantiles_within_relative_error() {
        // 1..=100_000 uniformly: p50 ~ 50_000, p99 ~ 99_000. The sketch
        // guarantees <= 12.5% relative error at the bucket midpoint.
        let mut s = QuantileSketch::new();
        for v in 1..=100_000u64 {
            s.record(v);
        }
        let p50 = s.quantile(0.50);
        let p99 = s.quantile(0.99);
        assert!((p50 - 50_000.0).abs() / 50_000.0 < 0.13, "p50 estimate {p50}");
        assert!((p99 - 99_000.0).abs() / 99_000.0 < 0.13, "p99 estimate {p99}");
    }

    #[test]
    fn sketch_empty_and_merge() {
        let empty = QuantileSketch::new();
        assert_eq!(empty.count(), 0);
        assert_eq!(empty.quantile(0.99), 0.0);

        let mut a = QuantileSketch::new();
        let mut b = QuantileSketch::new();
        for _ in 0..90 {
            a.record(1_000);
        }
        for _ in 0..10 {
            b.record(1_000_000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 100);
        // p50 sits in the 1k cluster, p99 in the 1M cluster.
        assert!((a.quantile(0.5) - 1_000.0).abs() / 1_000.0 < 0.13);
        assert!((a.quantile(0.99) - 1_000_000.0).abs() / 1_000_000.0 < 0.13);
    }

    #[test]
    fn sketch_bucket_ordering_is_monotone() {
        // Bucket index must be non-decreasing in the value, and the
        // representative value must stay within 12.5% of any member.
        let mut prev = 0usize;
        let mut v = 1u64;
        while v < u64::MAX / 3 {
            let b = sketch_bucket(v);
            assert!(b >= prev, "bucket not monotone at {v}");
            assert!(b < SKETCH_BUCKETS);
            let rep = sketch_value(b);
            assert!((rep - v as f64).abs() / v as f64 <= 0.125 + 1e-9, "rep {rep} for {v}");
            prev = b;
            v = v * 3 / 2 + 1;
        }
    }

    #[test]
    fn rss_probe_reports_on_linux() {
        if let Some(b) = resident_set_bytes() {
            assert!(b > 0);
        }
    }
}
