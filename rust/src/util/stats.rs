//! Descriptive statistics + timing helpers shared by the trainer, the
//! coordinator's report tables, and the benchmark harness.

use std::time::{Duration, Instant};

/// Summary statistics over a sample of f64s.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 0.5),
            p95: percentile_sorted(&sorted, 0.95),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Pearson correlation coefficient (the STS-B-style metric).
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if x.is_empty() {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (a, b) in x.iter().zip(y) {
        sxy += (a - mx) * (b - my);
        sxx += (a - mx) * (a - mx);
        syy += (b - my) * (b - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

/// Matthews correlation coefficient for binary labels (the CoLA metric).
pub fn matthews_corr(pred: &[usize], gold: &[usize]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    let (mut tp, mut tn, mut fp, mut fal_n) = (0f64, 0f64, 0f64, 0f64);
    for (&p, &g) in pred.iter().zip(gold) {
        match (p, g) {
            (1, 1) => tp += 1.0,
            (0, 0) => tn += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fal_n += 1.0,
            _ => panic!("matthews_corr expects binary labels"),
        }
    }
    let denom = ((tp + fp) * (tp + fal_n) * (tn + fp) * (tn + fal_n)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fal_n) / denom
    }
}

/// Classification accuracy.
pub fn accuracy(pred: &[usize], gold: &[usize]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hit = pred.iter().zip(gold).filter(|(p, g)| p == g).count();
    hit as f64 / pred.len() as f64
}

/// Wall-clock stopwatch with lap support.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Format a byte count in human units (memory-table output).
pub fn human_bytes(bytes: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{v:.0} {}", UNITS[u])
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a duration like "1h31m" / "57m" / "12.3s" (paper Fig 4b style).
pub fn human_duration(secs: f64) -> String {
    if secs >= 3600.0 {
        format!("{}h{:02}m", (secs / 3600.0) as u64, ((secs % 3600.0) / 60.0) as u64)
    } else if secs >= 60.0 {
        format!("{}m{:02}s", (secs / 60.0) as u64, (secs % 60.0) as u64)
    } else {
        format!("{secs:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn pearson_perfect() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn matthews_known_value() {
        // Perfect prediction -> 1.0; inverted -> -1.0.
        let gold = [0, 1, 0, 1, 1, 0];
        assert!((matthews_corr(&gold, &gold) - 1.0).abs() < 1e-12);
        let inv: Vec<usize> = gold.iter().map(|&g| 1 - g).collect();
        assert!((matthews_corr(&inv, &gold) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn accuracy_half() {
        assert!((accuracy(&[0, 1, 0, 1], &[0, 1, 1, 0]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn humanize() {
        assert_eq!(human_bytes(512.0), "512 B");
        assert_eq!(human_bytes(4.5 * 1024.0 * 1024.0 * 1024.0), "4.50 GiB");
        assert_eq!(human_duration(5460.0), "1h31m");
        assert_eq!(human_duration(93.0), "1m33s");
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&xs, 0.95) - 95.0).abs() < 1e-9);
        assert!((percentile_sorted(&xs, 0.0) - 0.0).abs() < 1e-9);
        assert!((percentile_sorted(&xs, 1.0) - 100.0).abs() < 1e-9);
    }
}
