//! Dependency-free substrates: PRNG, JSON, CLI parsing, statistics, thread
//! pool, logging, and a property-test runner. The offline build environment
//! provides no `rand`/`serde`/`clap`/`tokio`/`proptest`, so these are
//! first-class parts of the library (see DESIGN.md §4).

pub mod check;
pub mod cli;
pub mod json;
pub mod log;
pub mod rng;
pub mod stats;
pub mod threadpool;
