//! Minimal JSON parser/writer.
//!
//! Used for (a) reading the `artifacts/*.meta.json` files emitted by the AOT
//! pipeline (parameter flattening schema, entry-point shapes), (b) the
//! artifact manifest, and (c) machine-readable benchmark reports. No serde in
//! the offline environment, so this is a small, strict, well-tested
//! implementation of RFC 8259's essentials.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|x| if x.fract() == 0.0 { Some(x as i64) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]`-style access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index access; Null when out of range.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Expected-shape helpers that produce readable errors for meta files.
    pub fn expect_usize(&self, what: &str) -> anyhow::Result<usize> {
        self.as_usize().ok_or_else(|| anyhow::anyhow!("expected integer for {what}, got {self:?}"))
    }

    pub fn expect_str(&self, what: &str) -> anyhow::Result<&str> {
        self.as_str().ok_or_else(|| anyhow::anyhow!("expected string for {what}, got {self:?}"))
    }

    // -- construction helpers --------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // -- serialization -----------------------------------------------------

    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    pub fn dump_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    out.push_str(&"  ".repeat(indent + 1));
                    v.write_pretty(out, indent + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    out.push_str(&"  ".repeat(indent + 1));
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                    if i + 1 < o.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else if x.is_finite() {
        out.push_str(&format!("{x}"));
    } else {
        // JSON has no Inf/NaN; emit null (report code never feeds these).
        out.push_str("null");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (meta files never contain surrogate pairs).
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 sequence.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "3e2", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.dump()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_usize(), Some(1));
        assert_eq!(v.get("a").at(2).get("b").as_str(), Some("x\ny"));
        assert!(v.get("c").is_null());
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn roundtrip_pretty() {
        let src = r#"{"name":"psoft","shapes":[[2,3],[4]],"ok":true,"r":46}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["{", "[1,", "\"abc", "{\"a\" 1}", "tru", "1 2"] {
            assert!(Json::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn escapes_in_dump() {
        let v = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }
}
