//! Work-stealing-free, bounded thread pool.
//!
//! The coordinator fans suite jobs (task × method × seed grid) across cores,
//! and the blocked matmul in `linalg` parallelizes row panels. Tokio is not
//! available offline, and the workloads here are CPU-bound, so a plain
//! channel-fed pool is the right tool.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    panics: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Pool with `n` worker threads (min 1).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let panics = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let panics = Arc::clone(&panics);
                thread::Builder::new()
                    .name(format!("psoft-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                // A panicking job must not take the worker
                                // down: suites keep running and the failure
                                // count is surfaced at join time.
                                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                    panics.fetch_add(1, Ordering::SeqCst);
                                }
                            }
                            Err(_) => break, // sender dropped => shutdown
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, panics }
    }

    /// Pool sized to the machine.
    pub fn with_default_parallelism() -> ThreadPool {
        ThreadPool::new(default_parallelism())
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().expect("pool shut down").send(Box::new(f)).expect("worker hung up");
    }

    /// Number of jobs that panicked so far.
    pub fn panic_count(&self) -> usize {
        self.panics.load(Ordering::SeqCst)
    }

    /// Run a function over each item, collecting results in input order.
    /// Blocks until all items are done. Panics in `f` are propagated as a
    /// summary panic after all other items finish.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, thread::Result<R>)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.submit(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(item)));
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut failures = 0usize;
        for _ in 0..n {
            let (i, res) = rrx.recv().expect("result channel closed early");
            match res {
                Ok(r) => slots[i] = Some(r),
                Err(_) => failures += 1,
            }
        }
        if failures > 0 {
            panic!("{failures}/{n} pool jobs panicked");
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Machine parallelism, capped at 16 (beyond that, the tiny matmuls here
/// stop scaling and the suite jobs are the better axis to parallelize).
pub fn default_parallelism() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Parallel-for over index ranges, used by the matmul row-panel split.
/// Runs on scoped threads (no pool needed; panics propagate naturally).
pub fn par_chunks(n_items: usize, n_threads: usize, body: impl Fn(usize, usize) + Sync) {
    let n_threads = n_threads.max(1).min(n_items.max(1));
    if n_threads <= 1 || n_items == 0 {
        body(0, n_items);
        return;
    }
    let chunk = n_items.div_ceil(n_threads);
    thread::scope(|scope| {
        for t in 0..n_threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n_items);
            if lo >= hi {
                break;
            }
            let body = &body;
            scope.spawn(move || body(lo, hi));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect::<Vec<usize>>(), |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn submit_runs_everything() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn worker_survives_panic() {
        let pool = ThreadPool::new(2);
        pool.submit(|| panic!("injected"));
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..20 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // Give the panicking job time to be recorded before shutdown.
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 20);
    }

    #[test]
    #[should_panic(expected = "pool jobs panicked")]
    fn map_propagates_panics() {
        let pool = ThreadPool::new(2);
        let _ = pool.map(vec![1, 2, 3], |x| {
            if x == 2 {
                panic!("boom")
            } else {
                x
            }
        });
    }

    #[test]
    fn par_chunks_covers_range() {
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        par_chunks(97, 8, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }
}
