//! Thread pools: a channel-fed job pool for coarse suite work and a
//! persistent parallel-for pool for the matmul hot path.
//!
//! Two pools with different shapes:
//!
//! - [`ThreadPool`] — bounded, channel-fed. The coordinator fans suite
//!   jobs (task × method × seed grid) across cores; jobs are boxed
//!   closures, latency per job is irrelevant.
//! - [`ParPool`] — a long-lived parallel-for pool for the kernel hot
//!   path. `linalg::matmul` used to spawn scoped threads per large
//!   product ([`par_chunks`], kept below as the seed-era reference);
//!   that paid thread start-up and teardown on every call. `ParPool`
//!   workers are spawned once, park on a condvar between calls, and
//!   claim row-panel chunks from an atomic cursor — dispatching a
//!   [`ParPool::par_for`] performs **zero spawns and zero heap
//!   allocations**, which is what lets the warm train/serve/decode
//!   loops stay spawn- and allocation-free (pinned by
//!   `tests/zero_alloc.rs` / `tests/serve_alloc.rs`).
//!
//! The process-wide pool is lazily built by [`pool`] and shared by the
//! trainer, the `ServeCore` workers, and the benches. Its size follows
//! [`default_parallelism`]: `PSOFT_THREADS` env var if set, else the
//! `[runtime] threads` config key (via [`set_configured_threads`]), else
//! machine parallelism capped at 16.
//!
//! Every thread spawn in this module bumps a global counter
//! ([`thread_spawn_count`]) so tests can pin "warm loop ⇒ zero spawns".

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Process-wide count of OS threads spawned through this module. Warm
/// hot-path tests snapshot it around a measured window and assert the
/// delta is zero — the spawn-side analogue of the counting allocator.
static SPAWNS: AtomicU64 = AtomicU64::new(0);

pub fn thread_spawn_count() -> u64 {
    SPAWNS.load(Ordering::SeqCst)
}

fn note_spawn() {
    SPAWNS.fetch_add(1, Ordering::SeqCst);
}

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    panics: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Pool with `n` worker threads (min 1).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let panics = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let panics = Arc::clone(&panics);
                note_spawn();
                thread::Builder::new()
                    .name(format!("psoft-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                // A panicking job must not take the worker
                                // down: suites keep running and the failure
                                // count is surfaced at join time.
                                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                    panics.fetch_add(1, Ordering::SeqCst);
                                }
                            }
                            Err(_) => break, // sender dropped => shutdown
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, panics }
    }

    /// Pool sized to the machine.
    pub fn with_default_parallelism() -> ThreadPool {
        ThreadPool::new(default_parallelism())
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().expect("pool shut down").send(Box::new(f)).expect("worker hung up");
    }

    /// Number of jobs that panicked so far.
    pub fn panic_count(&self) -> usize {
        self.panics.load(Ordering::SeqCst)
    }

    /// Run a function over each item, collecting results in input order.
    /// Blocks until all items are done. Panics in `f` are propagated as a
    /// summary panic after all other items finish.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, thread::Result<R>)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.submit(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(item)));
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut failures = 0usize;
        for _ in 0..n {
            let (i, res) = rrx.recv().expect("result channel closed early");
            match res {
                Ok(r) => slots[i] = Some(r),
                Err(_) => failures += 1,
            }
        }
        if failures > 0 {
            panic!("{failures}/{n} pool jobs panicked");
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Thread-count resolution
// ---------------------------------------------------------------------------

/// `[runtime] threads` from the active config (0 = unset). Applied at
/// startup by `main` before any large kernel runs; a late call cannot
/// resize an already-built global [`pool`].
static CONFIGURED_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Install the `[runtime] threads` config value (0 clears it back to
/// auto). `PSOFT_THREADS` still wins — see [`default_parallelism`].
pub fn set_configured_threads(n: usize) {
    CONFIGURED_THREADS.store(n, Ordering::SeqCst);
}

/// `PSOFT_THREADS` parsed once per process (the hot path asks for the
/// thread count on every large matmul; re-reading the environment there
/// would allocate).
fn env_thread_override() -> Option<usize> {
    static ENV: OnceLock<Option<usize>> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("PSOFT_THREADS").ok().and_then(|s| s.trim().parse().ok()).filter(|&n| n >= 1)
    })
}

/// Worker-thread count, by precedence:
///
/// 1. `PSOFT_THREADS` environment variable (≥ 1);
/// 2. `[runtime] threads` config key ([`set_configured_threads`]);
/// 3. machine parallelism capped at 16 (beyond that, the tiny matmuls
///    here stop scaling and the suite jobs are the better axis to
///    parallelize — the overrides above are the escape hatch).
pub fn default_parallelism() -> usize {
    if let Some(n) = env_thread_override() {
        return n;
    }
    match CONFIGURED_THREADS.load(Ordering::SeqCst) {
        0 => thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16),
        n => n,
    }
}

// ---------------------------------------------------------------------------
// ParPool: persistent parallel-for
// ---------------------------------------------------------------------------

/// A published parallel-for job. `body` is a caller-stack closure whose
/// lifetime is erased; soundness rests on `par_for` not returning until
/// every worker has finished with it (see the SAFETY note there).
#[derive(Clone, Copy)]
struct JobDesc {
    body: &'static (dyn Fn(usize, usize) + Sync),
    n_items: usize,
    grain: usize,
}

struct ParState {
    /// Bumped per published job; workers use it to tell "new job" from a
    /// spurious wakeup.
    seq: u64,
    job: Option<JobDesc>,
    /// Workers still inside the current job (participation barrier).
    running: usize,
    shutdown: bool,
}

struct ParShared {
    state: Mutex<ParState>,
    /// Signals workers: new job published, or shutdown.
    start: Condvar,
    /// Signals callers: job finished (`running == 0`) or job slot freed.
    done: Condvar,
    /// Atomic chunk cursor: workers claim `[cursor, cursor + grain)`.
    next: AtomicUsize,
    /// Chunks that panicked in the current job.
    panics: AtomicUsize,
}

thread_local! {
    /// True on ParPool worker threads and inside a caller's own
    /// participation window: a nested `par_for` from either runs inline
    /// (the pool is already saturated, and waiting on the job slot the
    /// current job holds would deadlock).
    static IN_PAR_POOL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Persistent parallel-for pool: `threads − 1` workers parked on a
/// condvar, plus the calling thread which always participates. See the
/// module docs for why this exists; see [`pool`] for the shared instance.
pub struct ParPool {
    shared: Arc<ParShared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ParPool {
    /// Pool with `threads` total lanes of parallelism (min 1): the caller
    /// is one lane, so `threads − 1` OS threads are spawned — a
    /// single-lane pool spawns nothing and runs every job inline.
    pub fn new(threads: usize) -> ParPool {
        let threads = threads.max(1);
        let shared = Arc::new(ParShared {
            state: Mutex::new(ParState { seq: 0, job: None, running: 0, shutdown: false }),
            start: Condvar::new(),
            done: Condvar::new(),
            next: AtomicUsize::new(0),
            panics: AtomicUsize::new(0),
        });
        let workers = (0..threads - 1)
            .map(|i| {
                let shared = Arc::clone(&shared);
                note_spawn();
                thread::Builder::new()
                    .name(format!("psoft-par-{i}"))
                    .spawn(move || Self::worker_loop(&shared))
                    .expect("spawn par worker")
            })
            .collect();
        ParPool { shared, workers }
    }

    /// Total lanes of parallelism (workers + the participating caller).
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    fn lock(&self) -> MutexGuard<'_, ParState> {
        // A worker can only panic inside catch_unwind, never while holding
        // the lock, but be robust to poisoning anyway.
        self.shared.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn worker_loop(shared: &ParShared) {
        IN_PAR_POOL.with(|f| f.set(true));
        let mut last_seq = 0u64;
        loop {
            let job = {
                let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
                loop {
                    if st.shutdown {
                        return;
                    }
                    match st.job {
                        Some(job) if st.seq != last_seq => {
                            last_seq = st.seq;
                            break job;
                        }
                        _ => st = shared.start.wait(st).unwrap_or_else(|e| e.into_inner()),
                    }
                }
            };
            Self::run_chunks(shared, job);
            let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
            st.running -= 1;
            if st.running == 0 {
                shared.done.notify_all();
            }
        }
    }

    /// Claim and run chunks until the cursor passes the end. Panics are
    /// counted, not propagated: a worker must survive to decrement
    /// `running`, and the caller must not unwind while workers still
    /// borrow the job body — the caller re-raises a summary panic after
    /// the barrier.
    fn run_chunks(shared: &ParShared, job: JobDesc) {
        loop {
            let lo = shared.next.fetch_add(job.grain, Ordering::Relaxed);
            if lo >= job.n_items {
                break;
            }
            let hi = (lo + job.grain).min(job.n_items);
            if catch_unwind(AssertUnwindSafe(|| (job.body)(lo, hi))).is_err() {
                shared.panics.fetch_add(1, Ordering::SeqCst);
            }
        }
    }

    /// Run `body(lo, hi)` over disjoint chunks of `0..n_items`, each at
    /// most `grain` wide, across the pool's lanes. Blocks until the whole
    /// range is done. No spawns, no allocations. Concurrent callers
    /// serialize on the single job slot; nested calls (from a worker or
    /// from inside a body) run inline.
    pub fn par_for(&self, n_items: usize, grain: usize, body: &(dyn Fn(usize, usize) + Sync)) {
        let grain = grain.max(1);
        if n_items == 0 {
            return;
        }
        if self.workers.is_empty() || n_items <= grain || IN_PAR_POOL.with(|f| f.get()) {
            body(0, n_items);
            return;
        }
        // SAFETY: the 'static lifetime is a lie confined to this call.
        // Workers only dereference `body` between claiming a chunk and
        // decrementing `running`, and this function does not return (or
        // unwind — chunk panics are deferred) until `running == 0`, so the
        // borrow cannot outlive the real closure.
        let job = JobDesc {
            body: unsafe {
                std::mem::transmute::<
                    &(dyn Fn(usize, usize) + Sync),
                    &'static (dyn Fn(usize, usize) + Sync),
                >(body)
            },
            n_items,
            grain,
        };
        {
            let mut st = self.lock();
            // One job slot: queued callers wait for the active job to clear.
            while st.job.is_some() {
                st = self.shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            self.shared.next.store(0, Ordering::Relaxed);
            self.shared.panics.store(0, Ordering::Relaxed);
            st.job = Some(job);
            st.seq += 1;
            st.running = self.workers.len();
        }
        self.shared.start.notify_all();

        // Participate; nested par_for from inside `body` must run inline.
        IN_PAR_POOL.with(|f| f.set(true));
        Self::run_chunks(&self.shared, job);
        IN_PAR_POOL.with(|f| f.set(false));

        let panicked = {
            let mut st = self.lock();
            while st.running > 0 {
                st = self.shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            st.job = None;
            self.shared.panics.load(Ordering::SeqCst)
        };
        // Free the job slot for queued callers.
        self.shared.done.notify_all();
        if panicked > 0 {
            panic!("{panicked} par_for chunks panicked");
        }
    }
}

impl Drop for ParPool {
    fn drop(&mut self) {
        {
            let mut st = self.lock();
            st.shutdown = true;
        }
        self.shared.start.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// The process-wide [`ParPool`], built on first use and never torn down.
/// Sized by [`default_parallelism`] at initialization time, so thread
/// overrides must be in place before the first large kernel runs.
pub fn pool() -> &'static ParPool {
    static POOL: OnceLock<ParPool> = OnceLock::new();
    POOL.get_or_init(|| ParPool::new(default_parallelism()))
}

/// Parallel-for over index ranges on **freshly spawned scoped threads**.
/// This is the seed-era primitive the matmul row-panel split used before
/// the persistent [`pool`] existed; it is kept as the reference
/// implementation behind the `pool_speedup_over_seed` bench metric and
/// for one-shot callers that must not touch the global pool.
pub fn par_chunks(n_items: usize, n_threads: usize, body: impl Fn(usize, usize) + Sync) {
    let n_threads = n_threads.max(1).min(n_items.max(1));
    if n_threads <= 1 || n_items == 0 {
        body(0, n_items);
        return;
    }
    let chunk = n_items.div_ceil(n_threads);
    thread::scope(|scope| {
        for t in 0..n_threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n_items);
            if lo >= hi {
                break;
            }
            let body = &body;
            note_spawn();
            scope.spawn(move || body(lo, hi));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    /// Serializes every test that spawns threads or asserts on the global
    /// spawn counter — libtest runs tests concurrently, so an unrelated
    /// pool construction would otherwise break a zero-spawn-delta assert.
    fn spawn_gate() -> MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        GATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn map_preserves_order() {
        let _gate = spawn_gate();
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect::<Vec<usize>>(), |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn submit_runs_everything() {
        let _gate = spawn_gate();
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn worker_survives_panic() {
        let _gate = spawn_gate();
        let pool = ThreadPool::new(2);
        pool.submit(|| panic!("injected"));
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..20 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // Give the panicking job time to be recorded before shutdown.
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 20);
    }

    #[test]
    #[should_panic(expected = "pool jobs panicked")]
    fn map_propagates_panics() {
        let _gate = spawn_gate();
        let pool = ThreadPool::new(2);
        let _ = pool.map(vec![1, 2, 3], |x| {
            if x == 2 {
                panic!("boom")
            } else {
                x
            }
        });
    }

    #[test]
    fn par_chunks_covers_range() {
        let _gate = spawn_gate();
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        par_chunks(97, 8, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn par_for_covers_range_exactly_once() {
        let _gate = spawn_gate();
        let pool = ParPool::new(4);
        // Odd sizes and grains: non-divisible tails, single-chunk jobs,
        // more chunks than workers.
        for &(n, grain) in &[(97usize, 5usize), (100, 100), (3, 1), (1, 7), (64, 16), (7, 2)] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.par_for(n, grain, &|lo, hi| {
                assert!(hi - lo <= grain.max(1));
                for h in &hits[lo..hi] {
                    h.fetch_add(1, Ordering::SeqCst);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                "n={n} grain={grain}: range not covered exactly once"
            );
        }
    }

    #[test]
    fn par_for_reuses_workers_across_calls() {
        let _gate = spawn_gate();
        let pool = ParPool::new(3);
        let spawns_before = thread_spawn_count();
        let total = Arc::new(AtomicU64::new(0));
        for _ in 0..50 {
            let total = Arc::clone(&total);
            pool.par_for(40, 4, &move |lo, hi| {
                total.fetch_add((hi - lo) as u64, Ordering::SeqCst);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 50 * 40);
        // The whole point: no spawn per call.
        assert_eq!(thread_spawn_count() - spawns_before, 0);
    }

    #[test]
    fn par_for_serializes_concurrent_callers() {
        let _gate = spawn_gate();
        let pool = Arc::new(ParPool::new(2));
        let total = Arc::new(AtomicU64::new(0));
        thread::scope(|scope| {
            for _ in 0..4 {
                let pool = Arc::clone(&pool);
                let total = Arc::clone(&total);
                scope.spawn(move || {
                    for _ in 0..25 {
                        let total = &total;
                        pool.par_for(30, 3, &move |lo, hi| {
                            total.fetch_add((hi - lo) as u64, Ordering::SeqCst);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), 4 * 25 * 30);
    }

    #[test]
    fn par_for_nested_runs_inline() {
        let _gate = spawn_gate();
        let pool = ParPool::new(4);
        let total = Arc::new(AtomicU64::new(0));
        let outer_total = Arc::clone(&total);
        let pool_ref = &pool;
        pool.par_for(8, 1, &move |_, _| {
            let inner_total = Arc::clone(&outer_total);
            // Nested call must complete (inline) instead of deadlocking on
            // the single job slot.
            pool_ref.par_for(5, 2, &move |lo, hi| {
                inner_total.fetch_add((hi - lo) as u64, Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 8 * 5);
    }

    #[test]
    #[should_panic(expected = "par_for chunks panicked")]
    fn par_for_propagates_panics_after_barrier() {
        let _gate = spawn_gate();
        let pool = ParPool::new(3);
        pool.par_for(10, 1, &|lo, _| {
            if lo == 4 {
                panic!("injected chunk failure");
            }
        });
    }

    #[test]
    fn par_for_single_lane_runs_inline() {
        let _gate = spawn_gate();
        let pool = ParPool::new(1);
        let spawns_before = thread_spawn_count();
        let hits: Vec<AtomicU64> = (0..10).map(|_| AtomicU64::new(0)).collect();
        pool.par_for(10, 3, &|lo, hi| {
            for h in &hits[lo..hi] {
                h.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        assert_eq!(thread_spawn_count() - spawns_before, 0);
    }
}
