//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports the patterns the `psoft` binary and the examples need:
//! `prog subcommand --flag --key value --key=value positional…`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// `known_flags` lists boolean options that never take a value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(stripped.to_string(), iter.next().unwrap());
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env(known_flags: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), known_flags)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}"))
            }
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}"))
            }
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}"))
            }
        }
    }

    /// Comma-separated list option, e.g. `--ranks 8,16,32`.
    pub fn list(&self, key: &str) -> Vec<String> {
        self.get(key)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect())
            .unwrap_or_default()
    }

    pub fn usize_list(&self, key: &str) -> anyhow::Result<Vec<usize>> {
        self.list(key)
            .iter()
            .map(|s| s.parse().map_err(|_| anyhow::anyhow!("--{key}: bad integer {s:?}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let raw = v(&["train", "--method", "psoft", "--rank=46", "--verbose", "ds1"]);
        let a = Args::parse(raw, &["verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("method"), Some("psoft"));
        assert_eq!(a.usize("rank", 0).unwrap(), 46);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["ds1"]);
    }

    #[test]
    fn trailing_unknown_flag() {
        let a = Args::parse(v(&["bench", "--fast"]), &[]);
        assert!(a.has_flag("fast"));
    }

    #[test]
    fn lists_and_defaults() {
        let a = Args::parse(v(&["sweep", "--ranks", "8,16,32"]), &[]);
        assert_eq!(a.usize_list("ranks").unwrap(), vec![8, 16, 32]);
        assert_eq!(a.usize("batch", 64).unwrap(), 64);
        assert_eq!(a.get_or("out", "reports"), "reports");
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(v(&["x", "--rank", "abc"]), &[]);
        assert!(a.usize("rank", 0).is_err());
    }
}
