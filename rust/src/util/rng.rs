//! Deterministic pseudo-random number generation.
//!
//! The environment has no `rand` crate, so we implement the generators we
//! need: SplitMix64 (seeding / stream splitting) and xoshiro256++ (the main
//! generator), plus the distribution helpers used across the code base
//! (uniform, normal via Box–Muller, Kaiming, shuffling, categorical choice).
//!
//! All experiment code takes an explicit `Rng` so every table/figure is
//! reproducible from its seed.

/// SplitMix64 — used to expand a single `u64` seed into generator state and
/// to derive independent child streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box–Muller.
    cached_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, cached_normal: None }
    }

    /// Derive an independent child stream, e.g. one per task or per layer.
    /// Mixing in a label keeps streams for different purposes decorrelated
    /// even when derived from the same parent in the same order.
    pub fn child(&mut self, label: u64) -> Rng {
        let mut sm = SplitMix64::new(self.next_u64() ^ label.wrapping_mul(0x9E3779B97F4A7C15));
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Rng { s, cached_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill a slice with N(0, std²) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f64) {
        for v in out.iter_mut() {
            *v = (self.normal() * std) as f32;
        }
    }

    /// Fill a slice with U(-a, a) samples (Kaiming-uniform style).
    pub fn fill_uniform(&mut self, out: &mut [f32], a: f64) {
        for v in out.iter_mut() {
            *v = self.uniform(-a, a) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn choice_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "all-zero weights");
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn child_streams_decorrelated() {
        let mut root = Rng::new(7);
        let mut c1 = root.child(1);
        let mut c2 = root.child(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            let u = r.uniform(-2.0, 5.0);
            assert!((-2.0..5.0).contains(&u));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Rng::new(13);
        let mut hits = [0usize; 3];
        for _ in 0..30_000 {
            hits[r.choice_weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(hits[2] > hits[1] && hits[1] > hits[0], "hits={hits:?}");
    }
}
