//! Property-based testing harness (proptest is unavailable offline).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` over `cases` random inputs
//! drawn by `gen`. On failure it retries the failing case with a simple
//! halving shrink over a shrinkable representation when provided, and always
//! reports the case seed so the failure is replayable.

use crate::util::rng::Rng;

/// Run a property over `cases` generated inputs. Panics with the case seed
/// on the first failure.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let case_seed = root.next_u64();
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed on case {case} (replay seed {case_seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Like `forall` but the generator receives a `size` hint that grows over
/// the run, so early cases are small (easier to debug) and later cases
/// stress larger shapes.
pub fn forall_sized<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    max_size: usize,
    mut gen: impl FnMut(&mut Rng, usize) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let case_seed = root.next_u64();
        let size = 1 + (case * max_size) / cases.max(1);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng, size);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed on case {case} size {size} (replay seed {case_seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Assertion helpers returning Result so properties compose with `?`.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

pub fn all_close(a: &[f32], b: &[f32], tol: f64, what: &str) -> Result<(), String> {
    ensure(a.len() == b.len(), format!("{what}: length {} vs {}", a.len(), b.len()))?;
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let (x, y) = (*x as f64, *y as f64);
        if (x - y).abs() > tol * (1.0 + x.abs().max(y.abs())) {
            return Err(format!("{what}: mismatch at {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(1, 50, |r| r.below(100), |_x| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        forall(2, 100, |r| r.below(10), |&x| ensure(x < 9, format!("x={x} too big")));
    }

    #[test]
    fn sized_generation_grows() {
        let mut max_seen = 0;
        forall_sized(3, 30, 64, |r, size| r.below(size.max(1)) + size, |&x| {
            max_seen = max_seen.max(x);
            Ok(())
        });
        assert!(max_seen > 32);
    }

    #[test]
    fn close_tolerance() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, "t").is_ok());
        assert!(close(1.0, 1.1, 1e-6, "t").is_err());
    }
}
