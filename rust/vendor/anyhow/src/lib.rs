//! Minimal vendored subset of the `anyhow` API.
//!
//! The offline build environment has no crates.io access (see
//! `psoft::util` — the same reason the main crate carries its own RNG,
//! JSON, and thread pool). This shim provides exactly the surface the
//! `psoft` crate uses: [`Error`], [`Result`], the [`anyhow!`] and
//! [`bail!`] macros, and the [`Context`] extension trait for `Result`
//! and `Option`. Errors are flattened to strings at construction; no
//! downcasting or backtraces.

use std::fmt;

/// A string-backed error value, mirroring `anyhow::Error`'s role as a
/// catch-all. Deliberately does **not** implement `std::error::Error`,
/// so the blanket `From<E: std::error::Error>` below never overlaps the
/// reflexive `From<Error> for Error` the `?` operator relies on.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap with an outer context line (`context: inner`).
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>`: `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Context-attachment extension for `Result` and `Option`, matching the
/// subset of `anyhow::Context` the crate uses.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_num(s: &str) -> Result<i32> {
        let n: i32 = s.parse().context("parsing number")?;
        if n < 0 {
            bail!("negative: {n}");
        }
        Ok(n)
    }

    #[test]
    fn question_mark_and_context() {
        assert_eq!(parse_num("4").unwrap(), 4);
        let e = parse_num("x").unwrap_err();
        assert!(e.to_string().starts_with("parsing number:"));
        let e = parse_num("-2").unwrap_err();
        assert_eq!(e.to_string(), "negative: -2");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = Context::context(v, "missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn anyhow_macro_forms() {
        assert_eq!(anyhow!("plain").to_string(), "plain");
        assert_eq!(anyhow!("x={}", 3).to_string(), "x=3");
        let s = String::from("owned");
        assert_eq!(anyhow!(s).to_string(), "owned");
    }
}
