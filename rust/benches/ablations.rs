//! Ablation benches: Table 6 (orthogonality of R), Table 7 (init scheme),
//! Table 16 (SVD n_iter), Fig 3 (tunable vectors), Fig 8a (inserted
//! modules), Fig 8b (Neumann terms).

// Style allowances shared by the bench/test crates: index loops mirror
// the math notation, and config structs are built default-then-override.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::field_reassign_with_default)]

use psoft::bench::{bench_decoder, bench_encoder, pretrained_backbone, time_ms, write_csv};
use psoft::config::{DataConfig, MethodKind, ModuleKind, PeftConfig, PsoftInit, TrainConfig};
use psoft::data::load_task;
use psoft::linalg::{cayley_exact, cayley_neumann, skew_from_params, skew_param_count, DMat};
use psoft::model::NativeModel;
use psoft::peft::decomp::principal_split;
use psoft::runtime::NativeBackend;
use psoft::train::train;
use psoft::util::rng::Rng;

fn fast() -> bool {
    std::env::var("PSOFT_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

fn main() {
    table6_orthogonality();
    table7_init();
    table16_svd_iters();
    fig3_tunable_vectors();
    fig8a_modules();
    fig8b_neumann();
}

fn run_decoder_job(peft: PeftConfig, task: &str, epochs: usize) -> (usize, f64, f64) {
    let cfg = bench_decoder();
    let bb = pretrained_backbone(&cfg, "dec", 200);
    let mut rng = Rng::new(77);
    let model = NativeModel::from_backbone(&bb, &peft, &mut rng);
    let params = model.num_adapter_params();
    let mut be = NativeBackend::new(model);
    let mut dc = DataConfig::new("mathqa", task);
    dc.n_train = if fast() { 48 } else { 256 };
    dc.n_val = 48;
    dc.n_test = 48;
    dc.seq_len = 32;
    let data = load_task(&dc, cfg.vocab_size).unwrap();
    let mut tc = TrainConfig::default();
    tc.epochs = epochs;
    tc.batch_size = 16;
    tc.lr = 2e-3;
    tc.head_lr = 2e-3;
    let report = train(&mut be, &data, &tc, peft.gamma_orth).unwrap();
    (params, report.test_metric, be.model.orth_defect())
}

/// Table 6: PiSSA+LoRA-XS with γ-regularized unconstrained R vs PSOFT with
/// strict Cayley orthogonality (half the parameters at equal rank).
fn table6_orthogonality() {
    println!("\n=== Table 6 (sim): effect of the orthogonality of R ===");
    let epochs = if fast() { 1 } else { 4 };
    let mut rows = Vec::new();
    for gamma in [0.0, 0.01, 0.1, 1.0] {
        let mut p = PeftConfig::new(MethodKind::LoraXs, 24);
        p.modules = bench_decoder().modules();
        p.gamma_orth = gamma;
        let (params, em, defect) = run_decoder_job(p, "gsm8k", epochs);
        println!("pissa+lora_xs γ={gamma:<5} params={params:<8} EM={em:.1}% defect={defect:.3}");
        rows.push(format!("lora_xs,{gamma},{params},{em:.2},{defect:.4}"));
    }
    for strict in [true, false] {
        let mut p = PeftConfig::new(MethodKind::Psoft, 24);
        p.modules = bench_decoder().modules();
        p.use_alpha = !strict;
        p.use_beta = !strict;
        let (params, em, defect) = run_decoder_job(p, "gsm8k", epochs);
        let label = if strict { "psoft_strict" } else { "psoft_relaxed" };
        println!("{label:<18} params={params:<8} EM={em:.1}% defect={defect:.3}");
        rows.push(format!("{label},0,{params},{em:.2},{defect:.4}"));
    }
    write_csv("table6_orthogonality", "config,gamma,params,exact_match,defect", &rows);
}

/// Table 7: PSOFT init variants A_orth·R·B vs A·R·B_orth vs symmetric.
fn table7_init() {
    println!("\n=== Table 7 (sim): effect of initialization ===");
    let cfg = bench_encoder();
    let bb = pretrained_backbone(&cfg, "enc", 200);
    let mut rows = Vec::new();
    for (label, init) in [
        ("a_orth", PsoftInit::AOrth),
        ("b_orth", PsoftInit::BOrth),
        ("symmetric", PsoftInit::Symmetric),
    ] {
        let mut p = PeftConfig::new(MethodKind::Psoft, 24);
        p.modules = cfg.modules();
        p.psoft_init = init;
        let mut rng = Rng::new(78);
        let model = NativeModel::from_backbone(&bb, &p, &mut rng);
        let mut be = NativeBackend::new(model);
        let mut dc = DataConfig::new("glue", "sst2");
        dc.n_train = if fast() { 48 } else { 256 };
        dc.n_val = 48;
        dc.n_test = 48;
        dc.seq_len = 24;
        let data = load_task(&dc, cfg.vocab_size).unwrap();
        let mut tc = TrainConfig::default();
        tc.epochs = if fast() { 1 } else { 4 };
        tc.batch_size = 32;
        tc.lr = 2e-3;
        tc.head_lr = 2e-3;
        let report = train(&mut be, &data, &tc, 0.0).unwrap();
        println!("{label:<10} sst2-sim accuracy = {:.1}", report.test_metric);
        rows.push(format!("{label},{:.2}", report.test_metric));
    }
    write_csv("table7_init", "init,accuracy", &rows);
}

/// Table 16: randomized-SVD n_iter — init time vs subspace accuracy
/// (relative reconstruction error of the rank-r principal part).
fn table16_svd_iters() {
    println!("\n=== Table 16 (sim): effect of SVD n_iter ===");
    let mut rng = Rng::new(79);
    // A weight with a decaying spectrum, like a pretrained layer.
    let d = 192;
    let n = 192;
    let r = 32;
    let u = psoft::linalg::orthonormal_columns(&DMat::randn(d, r * 2, 1.0, &mut rng));
    let v = psoft::linalg::orthonormal_columns(&DMat::randn(n, r * 2, 1.0, &mut rng));
    let mut w = DMat::zeros(d, n);
    for k in 0..r * 2 {
        let sigma = 8.0 * (-(k as f64) / 10.0).exp() + 0.05;
        for i in 0..d {
            for j in 0..n {
                w[(i, j)] += sigma * u[(i, k)] * v[(j, k)];
            }
        }
    }
    let w32: psoft::linalg::Mat = w.cast();
    let exact = principal_split(&w32, r, None, &mut rng);
    let exact_pri = {
        let (a, b) = exact.asymmetric_factors();
        psoft::linalg::matmul(&a, &b)
    };
    let mut rows = Vec::new();
    for n_iter in [0usize, 5, 10, 20] {
        let mut rng2 = Rng::new(80);
        let ms = time_ms(3, || {
            let _ = principal_split(&w32, r, Some(n_iter), &mut rng2);
        });
        let split = principal_split(&w32, r, Some(n_iter), &mut Rng::new(81));
        let (a, b) = split.asymmetric_factors();
        let pri = psoft::linalg::matmul(&a, &b);
        let rel = pri.dist(&exact_pri) / exact_pri.frobenius_norm();
        println!("n_iter={n_iter:<3} init={ms:>8.2} ms  rel-error vs exact SVD = {rel:.2e}");
        rows.push(format!("{n_iter},{ms:.3},{rel:.3e}"));
    }
    let mut rng3 = Rng::new(82);
    let ms_exact = time_ms(3, || {
        let _ = principal_split(&w32, r, None, &mut rng3);
    });
    println!("exact      init={ms_exact:>8.2} ms  (reference)");
    rows.push(format!("exact,{ms_exact:.3},0"));
    write_csv("table16_svd_iters", "n_iter,init_ms,rel_error", &rows);
}

/// Fig 3: tunable vectors α/β ablation on GSM-8K-sim.
fn fig3_tunable_vectors() {
    println!("\n=== Fig 3 (sim): effect of tunable vectors ===");
    let epochs = if fast() { 1 } else { 4 };
    let mut rows = Vec::new();
    for (label, ua, ub) in [
        ("none", false, false),
        ("alpha_only", true, false),
        ("beta_only", false, true),
        ("both", true, true),
    ] {
        let mut p = PeftConfig::new(MethodKind::Psoft, 24);
        p.modules = bench_decoder().modules();
        p.use_alpha = ua;
        p.use_beta = ub;
        let (params, em, _) = run_decoder_job(p, "gsm8k", epochs);
        println!("{label:<12} params={params:<8} EM={em:.1}%");
        rows.push(format!("{label},{params},{em:.2}"));
    }
    write_csv("fig3_tunable_vectors", "variant,params,exact_match", &rows);
}

/// Fig 8a: inserted modules × rank on GSM-8K-sim.
fn fig8a_modules() {
    println!("\n=== Fig 8a (sim): effect of inserted modules ===");
    let epochs = if fast() { 1 } else { 3 };
    let qkv = vec![ModuleKind::Q, ModuleKind::K, ModuleKind::V];
    let qkvud =
        vec![ModuleKind::Q, ModuleKind::K, ModuleKind::V, ModuleKind::U, ModuleKind::D];
    let all = bench_decoder().modules();
    let mut rows = Vec::new();
    for (label, modules) in [("qkv", qkv), ("qkvud", qkvud), ("all", all)] {
        for rank in [8usize, 24] {
            let mut p = PeftConfig::new(MethodKind::Psoft, rank);
            p.modules = modules.clone();
            let (params, em, _) = run_decoder_job(p, "gsm8k", epochs);
            println!("{label:<6} r={rank:<3} params={params:<8} EM={em:.1}%");
            rows.push(format!("{label},{rank},{params},{em:.2}"));
        }
    }
    write_csv("fig8a_modules", "modules,rank,params,exact_match", &rows);
}

/// Fig 8b: Neumann terms — orthogonality defect and per-transform cost vs
/// K, compared with the exact Cayley transform.
fn fig8b_neumann() {
    println!("\n=== Fig 8b (sim): effect of Neumann terms ===");
    let r = 46;
    let mut rng = Rng::new(83);
    let params: Vec<f64> = (0..skew_param_count(r)).map(|_| 0.05 * rng.normal()).collect();
    let q = skew_from_params(r, &params);
    let exact = cayley_exact(&q);
    let mut rows = Vec::new();
    for k in [1usize, 2, 3, 4, 5, 6, 8] {
        let ms = time_ms(5, || {
            let _ = cayley_neumann(&q, k);
        });
        let approx = cayley_neumann(&q, k);
        let err = approx.dist(&exact);
        let defect = psoft::linalg::orthogonality_defect(&approx);
        println!("K={k:<2} {ms:>7.3} ms  ‖R−R_exact‖={err:.2e}  defect={defect:.2e}");
        rows.push(format!("{k},{ms:.4},{err:.3e},{defect:.3e}"));
    }
    let ms_exact = time_ms(5, || {
        let _ = cayley_exact(&q);
    });
    println!("exact {ms_exact:>7.3} ms");
    rows.push(format!("exact,{ms_exact:.4},0,0"));
    write_csv("fig8b_neumann", "terms,ms,err_vs_exact,defect", &rows);
}
