//! Geometry + dynamics benches: Figs 9/10 (pairwise-angle preservation
//! under strict vs relaxed PSOFT) and Fig 11 (loss curves across PSOFT
//! ranks vs OFT variants).

// Style allowances shared by the bench/test crates: index loops mirror
// the math notation, and config structs are built default-then-override.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::field_reassign_with_default)]

use psoft::bench::{bench_encoder, pretrained_backbone, write_csv};
use psoft::config::{DataConfig, MethodKind, ModuleKind, PeftConfig, TrainConfig};
use psoft::data::load_task;
use psoft::geometry::{angles_to_csv, geometry_deviation, pairwise_angles};
use psoft::model::NativeModel;
use psoft::runtime::NativeBackend;
use psoft::train::train;
use psoft::util::rng::Rng;

fn fast() -> bool {
    std::env::var("PSOFT_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

fn main() {
    fig9_10_angles();
    fig11_loss_curves();
}

fn fig9_10_angles() {
    println!("\n=== Figs 9/10 (sim): pairwise angle preservation ===");
    let cfg = bench_encoder();
    let bb = pretrained_backbone(&cfg, "enc", 200);
    let layer = cfg.n_layers / 2;
    let w_pre = bb.weight(layer, ModuleKind::Q).as_f32().clone();
    let k = 8;
    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/fig10_pre.csv", angles_to_csv(&pairwise_angles(&w_pre, k))).unwrap();

    let mut dc = DataConfig::new("glue", "cola");
    dc.n_train = if fast() { 48 } else { 160 };
    dc.n_val = 48;
    dc.n_test = 48;
    dc.seq_len = 24;
    let task = load_task(&dc, cfg.vocab_size).unwrap();
    let mut tc = TrainConfig::default();
    tc.epochs = if fast() { 1 } else { 4 };
    tc.batch_size = 32;
    tc.lr = 2e-3;
    tc.head_lr = 2e-3;

    let mut rows = Vec::new();
    for (label, relaxed) in [("strict", false), ("relaxed", true)] {
        let mut p = PeftConfig::new(MethodKind::Psoft, 24);
        p.modules = cfg.modules();
        p.use_alpha = relaxed;
        p.use_beta = relaxed;
        let mut rng = Rng::new(101);
        let model = NativeModel::from_backbone(&bb, &p, &mut rng);
        let mut be = NativeBackend::new(model);
        let report = train(&mut be, &task, &tc, 0.0).unwrap();
        let merged = be.model.to_backbone();
        let w_final = merged.weight(layer, ModuleKind::Q).as_f32();
        let (d_angle, d_norm) = geometry_deviation(&w_pre, w_final, k);
        println!(
            "{label:<8} metric={:.1} max|Δangle|={:.4}° max relΔnorm={:.5} defect={:.4}",
            report.test_metric,
            d_angle.to_degrees(),
            d_norm,
            be.model.orth_defect()
        );
        std::fs::write(
            format!("reports/fig10_{label}.csv"),
            angles_to_csv(&pairwise_angles(w_final, k)),
        )
        .unwrap();
        rows.push(format!(
            "{label},{:.4},{:.6},{:.4}",
            d_angle.to_degrees(),
            d_norm,
            be.model.orth_defect()
        ));
    }
    write_csv("fig9_10_summary", "variant,max_dangle_deg,max_rel_dnorm,defect", &rows);
    // Shape claim: strict preserves angles far better than relaxed moves
    // them (strict deviation should be tiny).
}

fn fig11_loss_curves() {
    println!("\n=== Fig 11 (sim): loss curves across ranks and OFT variants ===");
    let cfg = bench_encoder();
    let bb = pretrained_backbone(&cfg, "enc", 200);
    let mut dc = DataConfig::new("glue", "cola");
    dc.n_train = if fast() { 48 } else { 160 };
    dc.n_val = 48;
    dc.n_test = 48;
    dc.seq_len = 24;
    let task = load_task(&dc, cfg.vocab_size).unwrap();
    let mut tc = TrainConfig::default();
    tc.epochs = if fast() { 1 } else { 5 };
    tc.batch_size = 32;
    tc.lr = 2e-3;
    tc.head_lr = 2e-3;

    let mut configs: Vec<(String, PeftConfig)> = Vec::new();
    for r in [4usize, 16, 46] {
        let mut p = PeftConfig::new(MethodKind::Psoft, r);
        p.modules = cfg.modules();
        configs.push((format!("psoft_r{r}"), p));
    }
    let mut p_oft = PeftConfig::new(MethodKind::OftV2, 8);
    p_oft.modules = cfg.modules();
    configs.push(("oftv2".into(), p_oft));
    let mut p_boft = PeftConfig::new(MethodKind::Boft, 8);
    p_boft.modules = cfg.modules();
    p_boft.boft_b = 2;
    p_boft.boft_m = 4;
    configs.push(("boft".into(), p_boft));

    let mut curves: Vec<(String, Vec<f64>)> = Vec::new();
    for (label, p) in configs {
        let mut rng = Rng::new(102);
        let model = NativeModel::from_backbone(&bb, &p, &mut rng);
        let mut be = NativeBackend::new(model);
        let report = train(&mut be, &task, &tc, 0.0).unwrap();
        println!(
            "{label:<10} final train loss {:.4} (metric {:.1})",
            report.final_loss, report.test_metric
        );
        curves.push((label, report.loss_curve));
    }
    let max_len = curves.iter().map(|(_, c)| c.len()).max().unwrap_or(0);
    let mut rows = Vec::new();
    for i in 0..max_len {
        let mut row = format!("{i}");
        for (_, c) in &curves {
            row.push_str(&c.get(i).map(|l| format!(",{l:.5}")).unwrap_or(",".into()));
        }
        rows.push(row);
    }
    let header = format!(
        "step,{}",
        curves.iter().map(|(l, _)| l.clone()).collect::<Vec<_>>().join(",")
    );
    write_csv("fig11_loss_curves", &header, &rows);
    // Shape claim: larger PSOFT ranks approach the OFT-variant loss curves
    // (Appendix L) — higher-rank final loss ≤ lower-rank final loss.
    let final_of = |label: &str| {
        curves
            .iter()
            .find(|(l, _)| l == label)
            .and_then(|(_, c)| c.last().copied())
            .unwrap_or(f64::NAN)
    };
    assert!(
        final_of("psoft_r46") <= final_of("psoft_r4") + 0.05,
        "rank-46 should train at least as fast as rank-4"
    );
}
