//! Accounting benches — regenerate the analytic tables:
//! Table 8 (trainable-parameter formulas), Table 9 (activation memory per
//! transformer layer), Tables 13/15 (low-budget parameter matches),
//! Tables 17/18 (rank sweeps: params + memory), Fig 4a (memory vs batch).
//!
//! These reproduce the paper's *numbers* exactly where the quantity is
//! analytic (Appendix D/E formulas at paper shapes) and check the method
//! orderings the paper reports.

// Style allowances shared by the bench/test crates: index loops mirror
// the math notation, and config structs are built default-then-override.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::field_reassign_with_default)]

use psoft::bench::write_csv;
use psoft::config::{MethodKind, PeftConfig};
use psoft::memmodel::{
    activation::{method_delta_bytes, transformer_layer_bytes, ActShape},
    params::{paper_params, psoft_rank_for_budget, PaperModel},
    peak_memory_estimate,
};
use psoft::peft::closed_form_params;

fn main() {
    table8();
    table9();
    table13_15();
    table17_18();
    fig4a();
}

/// Table 8: closed-form parameter counts per linear layer (d = n = 4096,
/// r = 8 reference shapes) — and the PSOFT formula r(r−1)/2 + 2r.
fn table8() {
    println!("\n=== Table 8: trainable parameters per linear layer (d=n=4096) ===");
    let (d, n) = (4096, 4096);
    let mut rows = Vec::new();
    for m in MethodKind::ALL {
        let rank = match m {
            MethodKind::Psoft => 352,
            MethodKind::LoraXs => 248,
            _ => 8,
        };
        let mut cfg = PeftConfig::new(m, rank);
        cfg.oft_block_size = 32;
        cfg.boft_m = 2;
        cfg.boft_b = 8;
        let p = closed_form_params(&cfg, d, n);
        println!("{:<10} r={:<4} params/layer = {}", m.name(), rank, p);
        rows.push(format!("{},{rank},{p}", m.name()));
    }
    write_csv("table8_params", "method,rank,params_per_layer", &rows);

    // Paper's exact PSOFT formula.
    let r = 46;
    assert_eq!(
        closed_form_params(&PeftConfig::new(MethodKind::Psoft, r), d, n),
        r * (r - 1) / 2 + 2 * r
    );
}

/// Table 9: activation memory per transformer layer at the paper's shape
/// (b=64, s=512, h=4096, a=32).
fn table9() {
    println!("\n=== Table 9: activation memory per transformer layer ===");
    let s = ActShape { batch: 64, seq: 512, hidden: 4096, heads: 32, ffn_mult: 4.0 };
    let mut rows = Vec::new();
    for m in MethodKind::ALL {
        let rank = match m {
            MethodKind::LoraXs => 136,
            MethodKind::Psoft => 46,
            _ => 8,
        };
        let mut cfg = PeftConfig::new(m, rank);
        cfg.boft_m = 2;
        let total = transformer_layer_bytes(&s, &cfg);
        let delta = method_delta_bytes(&s, &cfg);
        println!("{:<10} delta={:>14.3e} B  total={:>14.3e} B", m.name(), delta, total);
        rows.push(format!("{},{delta:.0},{total:.0}", m.name()));
    }
    write_csv("table9_actmem", "method,delta_bytes,total_bytes", &rows);

    // Paper ordering assertions.
    let layer = |m: MethodKind, r: usize| {
        transformer_layer_bytes(&s, &PeftConfig::new(m, r))
    };
    assert!(layer(MethodKind::Goft, 0) > layer(MethodKind::Boft, 0));
    assert!(layer(MethodKind::Boft, 0) > layer(MethodKind::Dora, 8));
    assert!(layer(MethodKind::Psoft, 46) < layer(MethodKind::Lora, 8));
}

/// Tables 13/15: budget-matched configurations — verify the paper's
/// #Params alignments (e.g. PSOFT_r168 ≈ BOFT(m=2,b=2) ≈ 1.2M on
/// LLaMA-3.2-3B Q,K,V).
fn table13_15() {
    println!("\n=== Tables 13/15: low-budget parameter matching ===");
    let llama = PaperModel::llama32_3b();
    let mut rows = Vec::new();
    for (label, method, rank) in [
        ("psoft_r168", MethodKind::Psoft, 168),
        ("boft_b2_m2", MethodKind::Boft, 0),
        ("goftv2", MethodKind::Goft, 0),
        ("qgoftv2", MethodKind::QGoft, 0),
        ("lora_r1", MethodKind::Lora, 1),
    ] {
        let mut p = PeftConfig::new(method, rank.max(1));
        p.boft_b = 2;
        p.boft_m = 2;
        p.modules = vec![
            psoft::config::ModuleKind::Q,
            psoft::config::ModuleKind::K,
            psoft::config::ModuleKind::V,
        ];
        let params = psoft::memmodel::model_trainable_params(&llama.config(), &p);
        println!("{label:<12} params = {params}");
        rows.push(format!("{label},{params}"));
    }
    write_csv("table13_params", "config,params", &rows);

    // Table 4 headline: PSOFT r=352 ≈ LoRA r=8 budget on LLaMA-3B all
    // linears.
    let r_matched = psoft_rank_for_budget(8, 3072, 3072);
    println!("budget-matched PSOFT rank for LoRA r=8 @ d=3072: {r_matched} (paper uses 352)");
    assert!((300..=420).contains(&r_matched));
}

/// Tables 17/18: rank sweep — params grow as r(r−1)/2+2r, memory stays
/// nearly flat at small r (the paper's "memory usage remains stable").
fn table17_18() {
    println!("\n=== Tables 17/18: PSOFT rank sweep (params + projected memory) ===");
    let model = PaperModel::deberta_v3_base().config();
    let mut rows = Vec::new();
    let mut last_mem = 0.0;
    for r in [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512] {
        let mut p = PeftConfig::new(MethodKind::Psoft, r);
        p.modules = model.modules();
        let params = psoft::memmodel::model_trainable_params(&model, &p);
        let mem = peak_memory_estimate(&model, &p, 64, 64);
        println!("r={r:<4} params={params:<10} mem={:.2} GiB", mem / (1u64 << 30) as f64);
        rows.push(format!("{r},{params},{mem:.0}"));
        last_mem = mem;
    }
    // Flatness: r=64 within 25% of r=1.
    let mut p1 = PeftConfig::new(MethodKind::Psoft, 1);
    p1.modules = model.modules();
    let m1 = peak_memory_estimate(&model, &p1, 64, 64);
    let mut p64 = PeftConfig::new(MethodKind::Psoft, 64);
    p64.modules = model.modules();
    let m64 = peak_memory_estimate(&model, &p64, 64, 64);
    assert!(m64 / m1 < 1.25, "memory should stay nearly flat: {m1} vs {m64}");
    let _ = last_mem;
    write_csv("table17_rank_sweep", "rank,params,mem_bytes", &rows);
}

/// Fig 4a: memory vs batch size on ViT-B/16 shapes for the four headline
/// methods; the paper's ordering must hold at every batch size.
fn fig4a() {
    println!("\n=== Fig 4a: projected memory vs batch size (ViT-B/16) ===");
    let model = PaperModel::vit_b16().config();
    let mut rows = Vec::new();
    for batch in [8usize, 16, 32, 64] {
        let mem = |m: MethodKind, r: usize| {
            let mut p = PeftConfig::new(m, r.max(1));
            p.modules = model.modules();
            peak_memory_estimate(&model, &p, batch, 197)
        };
        let goft = mem(MethodKind::Goft, 1);
        let boft = mem(MethodKind::Boft, 1);
        let lora = mem(MethodKind::Lora, 8);
        let psoft = mem(MethodKind::Psoft, 46);
        println!(
            "batch={batch:<3} goft={:>8.2} GiB boft={:>7.2} GiB lora={:>6.2} GiB psoft={:>6.2} GiB",
            goft / 1.074e9,
            boft / 1.074e9,
            lora / 1.074e9,
            psoft / 1.074e9
        );
        assert!(goft > boft && boft > lora && lora > psoft);
        rows.push(format!("{batch},{goft:.0},{boft:.0},{lora:.0},{psoft:.0}"));
    }
    write_csv("fig4a_memory_vs_batch", "batch,goft,boft,lora,psoft", &rows);
}
