//! Serve bench: multi-adapter serving throughput/latency over one shared
//! frozen backbone — 1 vs 4 vs 16 adapters on the same fixed worker pool.
//! Emits `BENCH_serve.json`, the baseline the CI bench gate diffs against
//! (see `tools/bench_gate`). `PSOFT_BENCH_FAST=1` switches to the short
//! deterministic smoke mode CI runs.
//!
//! The per-request shapes are kept below the matmul threading thresholds
//! so each worker runs single-threaded compute: measured scaling is pure
//! scheduler parallelism across adapters, not nested matmul threading.

// Style allowances shared by the bench/test crates: index loops mirror
// the math notation, and config structs are built default-then-override.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::field_reassign_with_default)]

use psoft::bench::{bench_decoder, bench_encoder, write_csv};
use psoft::config::{BackboneDtype, MethodKind, ModelConfig, ModuleKind, PeftConfig};
use psoft::coordinator::serve_report;
use psoft::model::native::{Batch, Target};
use psoft::model::{Backbone, NativeModel};
use psoft::peft::AdapterId;
use psoft::runtime::serve::{Request, ServeCore, ServeOptions, SubmitOptions, Ticket};
use psoft::runtime::Hyper;
use psoft::util::json::Json;
use psoft::util::rng::Rng;
use psoft::util::stats::Stopwatch;
use psoft::util::threadpool::default_parallelism;
use std::sync::Arc;

fn fast() -> bool {
    std::env::var("PSOFT_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

fn submit_train(core: &ServeCore, id: AdapterId, batch: &Arc<Batch>, hyper: Hyper, t: &Ticket) {
    core.submit(id, Request::Train { batch: Arc::clone(batch), hyper }, t, SubmitOptions::default())
        .into_result()
        .unwrap();
}

fn submit_eval(core: &ServeCore, id: AdapterId, batch: &Arc<Batch>, t: &Ticket) {
    core.submit(id, Request::Eval { batch: Arc::clone(batch) }, t, SubmitOptions::default())
        .into_result()
        .unwrap();
}

/// The adapter mix cycled across registrations: the paper's method plus
/// three baselines, all on Q,V. PSOFT uses randomized-SVD init so 16
/// registrations stay cheap.
fn peft_for(i: usize) -> (String, PeftConfig) {
    let modules = vec![ModuleKind::Q, ModuleKind::V];
    match i % 4 {
        0 => {
            let mut p = PeftConfig::new(MethodKind::Psoft, 16).with_modules(modules);
            p.svd_n_iter = Some(2);
            ("psoft_r16".to_string(), p)
        }
        1 => ("lora_r8".to_string(), PeftConfig::new(MethodKind::Lora, 8).with_modules(modules)),
        2 => {
            let mut p = PeftConfig::new(MethodKind::OftV2, 8).with_modules(modules);
            p.oft_block_size = 16;
            ("oftv2_b16".to_string(), p)
        }
        _ => {
            let mut p = PeftConfig::new(MethodKind::Boft, 8).with_modules(modules);
            p.boft_b = 4;
            p.boft_m = 2;
            ("boft_b4m2".to_string(), p)
        }
    }
}

fn synth_batch(cfg: &ModelConfig, bsz: usize, seq: usize, seed: u64) -> Arc<Batch> {
    let mut rng = Rng::new(seed);
    let tokens: Vec<i32> = (0..bsz * seq).map(|_| rng.below(cfg.vocab_size) as i32).collect();
    let labels: Vec<usize> = (0..bsz).map(|b| (tokens[b * seq] as usize) % 2).collect();
    Arc::new(Batch {
        batch: bsz,
        seq,
        tokens,
        pad: vec![1.0; bsz * seq],
        target: Target::Class(labels),
    })
}

struct ConfigResult {
    adapters: usize,
    requests: u64,
    wall_secs: f64,
    reqs_per_sec: f64,
    mean_service_ms: f64,
    mean_latency_ms: f64,
}

fn main() {
    let cfg = bench_encoder();
    let mut rng = Rng::new(95);
    let bb = Arc::new(Backbone::random(&cfg, &mut rng));
    let workers = default_parallelism().min(8);
    let (bsz, seq) = (4usize, 12usize);
    let rounds = if fast() { 6usize } else { 24 };
    let hyper = Hyper::default();
    println!(
        "=== serve bench: {workers} workers, batch {bsz}x{seq}, \
         {rounds} rounds of train+eval per adapter ==="
    );

    let mut results: Vec<ConfigResult> = Vec::new();
    let mut csv_rows = Vec::new();
    for &n_adapters in &[1usize, 4, 16] {
        let opts = ServeOptions {
            workers,
            queue_cap: 2 * rounds + 4,
            burst: 4,
            ..Default::default()
        };
        let core = ServeCore::new(Arc::clone(&bb), opts);
        let ids: Vec<AdapterId> = (0..n_adapters)
            .map(|i| {
                let (label, peft) = peft_for(i);
                core.register(&label, &peft, 1000 + i as u64)
            })
            .collect();
        let batches: Vec<Arc<Batch>> =
            (0..n_adapters).map(|a| synth_batch(&cfg, bsz, seq, 77 + a as u64)).collect();

        // Warmup: one train + one eval per adapter (sizes every buffer).
        let warm = Ticket::new(bsz);
        for (a, id) in ids.iter().enumerate() {
            submit_train(&core, *id, &batches[a], hyper, &warm);
            warm.wait().unwrap();
            submit_eval(&core, *id, &batches[a], &warm);
            warm.wait().unwrap();
        }

        let before: Vec<_> = ids.iter().map(|id| core.stats(*id).unwrap()).collect();
        let mut tickets: Vec<Ticket> = Vec::with_capacity(rounds * n_adapters * 2);
        let sw = Stopwatch::start();
        for _ in 0..rounds {
            for (a, id) in ids.iter().enumerate() {
                let tt = Ticket::new(bsz);
                submit_train(&core, *id, &batches[a], hyper, &tt);
                tickets.push(tt);
                let te = Ticket::new(bsz);
                submit_eval(&core, *id, &batches[a], &te);
                tickets.push(te);
            }
        }
        core.drain();
        let wall_secs = sw.secs();
        for t in &tickets {
            t.wait().unwrap();
        }

        let requests = (rounds * n_adapters * 2) as u64;
        let mut lat_sum = 0u64;
        let mut svc_sum = 0u64;
        for (id, b) in ids.iter().zip(&before) {
            let s = core.stats(*id).unwrap();
            lat_sum += s.total_latency_ns - b.total_latency_ns;
            svc_sum += s.service_ns - b.service_ns;
        }
        let reqs_per_sec = requests as f64 / wall_secs.max(1e-9);
        let mean_latency_ms = lat_sum as f64 / requests as f64 / 1e6;
        let mean_service_ms = svc_sum as f64 / requests as f64 / 1e6;
        println!(
            "adapters {n_adapters:>2}: {requests:>5} reqs in {wall_secs:>7.3}s \
             = {reqs_per_sec:>8.2} req/s (svc {mean_service_ms:.3} ms, lat {mean_latency_ms:.3} ms)"
        );
        csv_rows.push(format!(
            "{n_adapters},{requests},{wall_secs:.4},{reqs_per_sec:.3},\
             {mean_service_ms:.4},{mean_latency_ms:.4}"
        ));
        if n_adapters == 16 {
            let report = serve_report("serve bench (16 adapters)", &core, wall_secs, workers);
            println!("{}", report.to_markdown());
        }
        results.push(ConfigResult {
            adapters: n_adapters,
            requests,
            wall_secs,
            reqs_per_sec,
            mean_service_ms,
            mean_latency_ms,
        });
    }
    write_csv(
        "serve_bench",
        "adapters,requests,wall_s,reqs_per_sec,mean_service_ms,mean_latency_ms",
        &csv_rows,
    );

    // Shared-backbone accounting: frozen bytes each extra adapter
    // references instead of copying.
    let (_, peft0) = peft_for(0);
    let mut mrng = Rng::new(7);
    let probe = NativeModel::from_backbone(&bb, &peft0, &mut mrng);
    let shared_mib = probe.shared_frozen_bytes() as f64 / (1024.0 * 1024.0);

    // backbone_dtype axis: quantize the same backbone to int8, serve a
    // short eval round through it (proves the dequant-fused path end to
    // end), and compare resident frozen bytes — the number the CI gate
    // holds at ≤ 0.35 of f32.
    let bb_q = Arc::new(bb.to_dtype(BackboneDtype::Int8));
    let frozen_mib_f32 = bb.resident_bytes() as f64 / (1024.0 * 1024.0);
    let frozen_mib_int8 = bb_q.resident_bytes() as f64 / (1024.0 * 1024.0);
    let int8_ratio = frozen_mib_int8 / frozen_mib_f32.max(1e-12);
    {
        let core =
            ServeCore::new(Arc::clone(&bb_q), ServeOptions { workers, ..Default::default() });
        let (label, peft) = peft_for(0);
        let id = core.register(&label, &peft, 2000);
        let batch = synth_batch(&cfg, bsz, seq, 177);
        let t = Ticket::new(bsz);
        submit_eval(&core, id, &batch, &t);
        t.wait().expect("int8-backbone eval");
    }
    println!(
        "shared frozen backbone: {frozen_mib_f32:.2} MiB f32 vs {frozen_mib_int8:.2} MiB int8 \
         ({int8_ratio:.3}x)"
    );

    // Merged-serving axis: one BOFT adapter (the costliest structured
    // per-token path in the zoo — m chained butterfly stages on top of the
    // dense matmul) decoding greedily on a decoder backbone, adapted vs
    // promoted to merged. The merged path strictly removes the per-token
    // adapter work, so its per-token time must not exceed the adapted
    // path's: the CI gate holds `merged_speedup_over_adapted` at the
    // committed floor (1.0). Per-mode time is the min of 3 runs (plus a
    // warmup) so shared-runner noise cannot fake a regression.
    let dcfg = bench_decoder();
    let mut drng = Rng::new(96);
    let dec_bb = Arc::new(Backbone::random(&dcfg, &mut drng));
    let prompt_len = 8usize;
    let dec_new = if fast() { 24usize } else { 64 };
    assert!(prompt_len + dec_new <= dcfg.max_seq);
    let prompt: Arc<Vec<i32>> =
        Arc::new((0..prompt_len).map(|t| (t * 7 % dcfg.vocab_size) as i32).collect());
    let mut boft = PeftConfig::new(MethodKind::Boft, 8)
        .with_modules(vec![ModuleKind::Q, ModuleKind::V]);
    boft.boft_b = 4;
    boft.boft_m = 2;
    let dec_core =
        ServeCore::new(Arc::clone(&dec_bb), ServeOptions { workers: 1, ..Default::default() });
    let did = dec_core.register("boft_merge", &boft, 3000);
    let run_gen = |expect: Option<&[i32]>| -> (f64, Vec<i32>) {
        let t = Ticket::new(dec_new);
        let sw = Stopwatch::start();
        dec_core
            .submit(
                did,
                Request::Generate {
                    prompt: Arc::clone(&prompt),
                    max_new_tokens: dec_new,
                    greedy: true,
                },
                &t,
                SubmitOptions::default(),
            )
            .into_result()
            .unwrap();
        dec_core.drain();
        t.wait().expect("merged-axis generation");
        let secs = sw.secs();
        let stream = t.with_tokens(|tok| tok.to_vec());
        if let Some(want) = expect {
            assert_eq!(stream, want, "merged stream must equal the adapted stream");
        }
        (secs, stream)
    };
    let measure = |expect: Option<&[i32]>| -> (f64, Vec<i32>) {
        let (_, stream) = run_gen(expect); // warmup sizes lanes + caches
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            best = best.min(run_gen(expect).0);
        }
        (best * 1e3 / dec_new as f64, stream)
    };
    let (adapted_ms_per_tok, adapted_stream) = measure(None);
    dec_core.promote(did).expect("promote for merged axis");
    let (merged_ms_per_tok, _) = measure(Some(&adapted_stream));
    let merged_speedup = adapted_ms_per_tok / merged_ms_per_tok.max(1e-12);
    // Extra bytes a merged twin pins per slot: one dense f32 copy of each
    // folded module (deterministic — gated at zero growth).
    let merged_twin_bytes: usize = dcfg.n_layers
        * boft
            .modules
            .iter()
            .map(|&m| {
                let (din, dout) = dcfg.module_shape(m);
                din * dout * 4
            })
            .sum::<usize>();
    let merged_twin_mib = merged_twin_bytes as f64 / (1024.0 * 1024.0);
    println!(
        "merged serving (boft_b4m2, {dec_new} greedy tokens): \
         {adapted_ms_per_tok:.3} ms/tok adapted vs {merged_ms_per_tok:.3} ms/tok merged \
         = {merged_speedup:.2}x; twin pins {merged_twin_mib:.3} MiB dense state"
    );

    let rps_at = |n: usize| -> f64 {
        results.iter().find(|c| c.adapters == n).map(|c| c.reqs_per_sec).unwrap_or(0.0)
    };
    let scaling = if rps_at(1) > 0.0 { rps_at(16) / rps_at(1) } else { 0.0 };
    println!(
        "16-adapter aggregate throughput = {scaling:.2}x single-adapter; \
         {shared_mib:.2} MiB frozen state shared per adapter"
    );

    let json = Json::obj(vec![
        (
            "workload",
            Json::Str(format!(
                "encoder_small; psoft/lora/oftv2/boft mix on Q,V; \
                 batch {bsz} x seq {seq}; paired train+eval requests"
            )),
        ),
        ("workers", Json::Num(workers as f64)),
        ("rounds", Json::Num(rounds as f64)),
        ("fast_mode", Json::Bool(fast())),
        (
            "configs",
            Json::Arr(
                results
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("adapters", Json::Num(c.adapters as f64)),
                            ("requests", Json::Num(c.requests as f64)),
                            ("wall_secs", Json::Num(c.wall_secs)),
                            ("reqs_per_sec", Json::Num(c.reqs_per_sec)),
                            ("mean_service_ms", Json::Num(c.mean_service_ms)),
                            ("mean_latency_ms", Json::Num(c.mean_latency_ms)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("reqs_per_sec_1", Json::Num(rps_at(1))),
        ("reqs_per_sec_16", Json::Num(rps_at(16))),
        ("scaling_16x_over_1x", Json::Num(scaling)),
        ("shared_frozen_mib_per_adapter", Json::Num(shared_mib)),
        ("shared_frozen_mib_f32", Json::Num(frozen_mib_f32)),
        ("shared_frozen_mib_int8", Json::Num(frozen_mib_int8)),
        ("int8_over_f32_ratio", Json::Num(int8_ratio)),
        ("merged_per_token_ms_adapted", Json::Num(adapted_ms_per_tok)),
        ("merged_per_token_ms_merged", Json::Num(merged_ms_per_tok)),
        ("merged_speedup_over_adapted", Json::Num(merged_speedup)),
        ("merged_twin_resident_mib", Json::Num(merged_twin_mib)),
    ]);
    std::fs::write("BENCH_serve.json", json.dump_pretty()).expect("write BENCH_serve.json");
    eprintln!("wrote BENCH_serve.json");
}
