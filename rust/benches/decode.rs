//! Decode bench: autoregressive generation through the KV-cache path —
//! model-level prefill and per-token latency, aggregate tokens/sec
//! through the serve core at 1 vs 4 vs 16 decoder adapters on one shared
//! frozen backbone, and a **continuous-batching axis**: a fixed 16-
//! generation workload on one adapter swept over `decode_batch` g = 1
//! (sequential baseline) / 4 / 16 lockstep lanes. Two paged-K/V axes
//! ride along: a **concurrent-lanes axis** joins 512 generations to one
//! group and asserts the pool holds exactly `ceil(len / PAGE_ROWS)`
//! pages per K/V table (memory scales with *active tokens*, not
//! lanes × max_seq monolithic rings), and a **TTFT axis** counts the
//! group steps a mid-flight joiner needs to reach its first token at
//! the default prefill chunk vs the tokenwise schedule. Emits
//! `BENCH_decode.json`, the baseline the CI bench gate diffs against
//! (see `tools/bench_gate`; refresh the committed copy with
//! `bench_gate --update-baselines`). `PSOFT_BENCH_FAST=1` switches to
//! the short deterministic smoke mode CI runs.
//!
//! Per-request shapes are `[1, d]`, far below the matmul threading
//! thresholds, so each worker decodes single-threaded: measured scaling
//! is pure scheduler parallelism across adapters.

// Style allowances shared by the bench/test crates: index loops mirror
// the math notation, and config structs are built default-then-override.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::field_reassign_with_default)]

use psoft::bench::{bench_decoder, write_csv};
use psoft::config::{MethodKind, ModuleKind, PeftConfig};
use psoft::linalg::{Workspace, PAGE_ROWS};
use psoft::model::native::{self, DecodeCache, DecodeLane, DecodeStream, GroupDecodeCache};
use psoft::model::Backbone;
use psoft::peft::AdapterId;
use psoft::runtime::serve::{Request, ServeCore, ServeOptions, SubmitOptions, Ticket};
use psoft::runtime::NativeBackend;
use psoft::util::json::Json;
use psoft::util::rng::Rng;
use psoft::util::stats::Stopwatch;
use psoft::util::threadpool::default_parallelism;
use std::sync::Arc;

fn fast() -> bool {
    std::env::var("PSOFT_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

fn submit_gen(core: &ServeCore, id: AdapterId, prompt: &Arc<Vec<i32>>, max_new: usize, t: &Ticket) {
    core.submit(
        id,
        Request::Generate { prompt: Arc::clone(prompt), max_new_tokens: max_new, greedy: true },
        t,
        SubmitOptions::default(),
    )
    .into_result()
    .unwrap();
}

/// The adapter mix cycled across registrations — the paper's method plus
/// three baselines, all on Q,V (randomized-SVD PSOFT init keeps 16
/// registrations cheap).
fn peft_for(i: usize) -> (String, PeftConfig) {
    let modules = vec![ModuleKind::Q, ModuleKind::V];
    match i % 4 {
        0 => {
            let mut p = PeftConfig::new(MethodKind::Psoft, 16).with_modules(modules);
            p.svd_n_iter = Some(2);
            ("psoft_r16".to_string(), p)
        }
        1 => ("lora_r8".to_string(), PeftConfig::new(MethodKind::Lora, 8).with_modules(modules)),
        2 => {
            let mut p = PeftConfig::new(MethodKind::OftV2, 8).with_modules(modules);
            p.oft_block_size = 16;
            ("oftv2_b16".to_string(), p)
        }
        _ => {
            let mut p = PeftConfig::new(MethodKind::Boft, 8).with_modules(modules);
            p.boft_b = 4;
            p.boft_m = 2;
            ("boft_b4m2".to_string(), p)
        }
    }
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

struct ConfigResult {
    adapters: usize,
    generations: u64,
    tokens: u64,
    wall_secs: f64,
    tokens_per_sec: f64,
}

fn main() {
    let cfg = bench_decoder();
    let mut rng = Rng::new(97);
    let bb = Arc::new(Backbone::random(&cfg, &mut rng));
    let workers = default_parallelism().min(8);
    let prompt_len = 8usize;
    let max_new = if fast() { 16usize } else { 32 };
    let gens_per_adapter = if fast() { 2usize } else { 6 };
    assert!(prompt_len + max_new <= cfg.max_seq);
    println!(
        "=== decode bench: {workers} workers, prompt {prompt_len}, \
         {max_new} new tokens, {gens_per_adapter} generations per adapter ==="
    );

    // --- Model-level prefill / per-token latency (single warm adapter) --
    let backend = NativeBackend::for_adapter(&bb, &peft_for(0).1, 1000);
    let mut ws = Workspace::new();
    let mut cache = DecodeCache::new();
    let prompt: Vec<i32> =
        (0..prompt_len).map(|_| rng.below(cfg.vocab_size) as i32).collect();
    let _warm = backend.generate(&prompt, max_new, true, &mut cache, &mut ws);
    let reps = if fast() { 3 } else { 10 };
    let mut srng = Rng::new(7);
    let mut prefill_times = Vec::with_capacity(reps);
    let mut token_times = Vec::with_capacity(reps);
    for _ in 0..reps {
        cache.ensure(&backend.model, &mut ws); // warm no-op + len reset
        let sw = Stopwatch::start();
        for &t in &prompt {
            native::decode_step(&backend.model, &mut cache, t, &mut ws)
                .expect("prompt fits max_seq");
        }
        prefill_times.push(sw.ms());
        let mut last = native::select_token(&cache, true, &mut srng);
        let sw2 = Stopwatch::start();
        for _ in 0..max_new {
            native::decode_step(&backend.model, &mut cache, last, &mut ws)
                .expect("generation fits max_seq");
            last = native::select_token(&cache, true, &mut srng);
        }
        token_times.push(sw2.ms() / max_new as f64);
    }
    let prefill_ms = median(prefill_times);
    let per_token_ms = median(token_times);
    println!(
        "model-level: prefill({prompt_len} tok) {prefill_ms:.3} ms, \
         per-token {per_token_ms:.4} ms"
    );

    // --- Batched [p, d] prefill vs the tokenwise schedule --------------
    // Same lane, same prompt, same `prefill_into` path: one 64-token
    // chunk vs 64 one-token chunks. The streams are bit-identical
    // (tests/decode.rs pins that); this measures the wall-clock win of
    // feeding the prompt through [p, d]-shaped projections and MLPs.
    let batch_prompt_len = 64usize;
    assert!(batch_prompt_len <= cfg.max_seq);
    let batch_prompt: Vec<i32> =
        (0..batch_prompt_len).map(|_| rng.below(cfg.vocab_size) as i32).collect();
    let mut lane = DecodeLane::new();
    lane.ensure(&backend.model, &mut ws);
    // Warm both chunk shapes so the measured reps hit the workspace pool.
    native::prefill_into(&backend.model, &mut lane, &batch_prompt, None, &mut ws)
        .expect("prompt fits max_seq");
    lane.reset();
    let mut tokenwise_times = Vec::with_capacity(reps);
    let mut batched_times = Vec::with_capacity(reps);
    for _ in 0..reps {
        lane.reset();
        let sw = Stopwatch::start();
        for t in 0..batch_prompt_len {
            native::prefill_into(
                &backend.model,
                &mut lane,
                &batch_prompt[t..t + 1],
                None,
                &mut ws,
            )
            .expect("prompt fits max_seq");
        }
        tokenwise_times.push(sw.ms());
        lane.reset();
        let sw = Stopwatch::start();
        native::prefill_into(&backend.model, &mut lane, &batch_prompt, None, &mut ws)
            .expect("prompt fits max_seq");
        batched_times.push(sw.ms());
    }
    lane.release(&mut ws);
    let prefill_tokenwise_ms = median(tokenwise_times);
    let prefill_batched_ms = median(batched_times);
    let prefill_batch_speedup = prefill_tokenwise_ms / prefill_batched_ms.max(1e-9);
    println!(
        "batched prefill({batch_prompt_len} tok): {prefill_batched_ms:.3} ms vs \
         {prefill_tokenwise_ms:.3} ms tokenwise = {prefill_batch_speedup:.2}x"
    );

    // --- Serve-level aggregate tokens/sec at 1/4/16 adapters -----------
    let mut results: Vec<ConfigResult> = Vec::new();
    let mut csv_rows = Vec::new();
    for &n_adapters in &[1usize, 4, 16] {
        let opts = ServeOptions {
            workers,
            queue_cap: 2 * gens_per_adapter + 4,
            burst: 4,
            // Pin the ungrouped path: these tokens_per_sec_{1,16} keys
            // gate the single-lane resumable decode the PR4 floors were
            // authored for; the group axis below sweeps g explicitly.
            decode_batch: 1,
            ..Default::default()
        };
        let core = ServeCore::new(Arc::clone(&bb), opts);
        let ids: Vec<AdapterId> = (0..n_adapters)
            .map(|i| {
                let (label, peft) = peft_for(i);
                core.register(&label, &peft, 2000 + i as u64)
            })
            .collect();
        let prompts: Vec<Arc<Vec<i32>>> = (0..n_adapters)
            .map(|a| {
                let mut prng = Rng::new(300 + a as u64);
                Arc::new(
                    (0..prompt_len).map(|_| prng.below(cfg.vocab_size) as i32).collect(),
                )
            })
            .collect();

        // Warmup: one generation per adapter sizes every KV-cache and
        // workspace pool.
        let warm = Ticket::new(max_new);
        for (a, id) in ids.iter().enumerate() {
            submit_gen(&core, *id, &prompts[a], max_new, &warm);
            warm.wait().unwrap();
        }

        let mut tickets: Vec<Ticket> = Vec::with_capacity(gens_per_adapter * n_adapters);
        let sw = Stopwatch::start();
        for _ in 0..gens_per_adapter {
            for (a, id) in ids.iter().enumerate() {
                let t = Ticket::new(max_new);
                submit_gen(&core, *id, &prompts[a], max_new, &t);
                tickets.push(t);
            }
        }
        core.drain();
        let wall_secs = sw.secs();
        let mut tokens = 0u64;
        for t in &tickets {
            let (_, emitted) = t.wait().unwrap();
            tokens += emitted as u64;
        }
        let generations = (gens_per_adapter * n_adapters) as u64;
        let tokens_per_sec = tokens as f64 / wall_secs.max(1e-9);
        println!(
            "adapters {n_adapters:>2}: {generations:>4} generations, {tokens:>6} tokens \
             in {wall_secs:>7.3}s = {tokens_per_sec:>9.1} tok/s"
        );
        csv_rows.push(format!(
            "{n_adapters},{generations},{tokens},{wall_secs:.4},{tokens_per_sec:.2}"
        ));
        results.push(ConfigResult {
            adapters: n_adapters,
            generations,
            tokens,
            wall_secs,
            tokens_per_sec,
        });
    }
    write_csv(
        "decode_bench",
        "adapters,generations,tokens,wall_s,tokens_per_sec",
        &csv_rows,
    );

    let tps_at = |n: usize| -> f64 {
        results.iter().find(|c| c.adapters == n).map(|c| c.tokens_per_sec).unwrap_or(0.0)
    };
    let scaling = if tps_at(1) > 0.0 { tps_at(16) / tps_at(1) } else { 0.0 };
    println!("16-adapter aggregate decode throughput = {scaling:.2}x single-adapter");

    // --- Continuous batching: g same-adapter generations in lockstep ----
    // Fixed workload (16 generations on ONE adapter), swept over the
    // group width: decode_batch = 1 is the sequential baseline (each
    // generation decodes alone), 16 advances all of them through one
    // [16, d] forward per position. Same-adapter work is serialized by
    // the scheduler, so the measured win is pure batching amortization.
    let total_gens = 16usize;
    let mut group_results: Vec<(usize, u64, f64, f64)> = Vec::new();
    let mut group_csv = Vec::new();
    for &g in &[1usize, 4, 16] {
        let opts = ServeOptions {
            workers: 2,
            queue_cap: total_gens + 4,
            burst: 4,
            decode_batch: g,
            ..Default::default()
        };
        let core = ServeCore::new(Arc::clone(&bb), opts);
        let (label, peft) = peft_for(0);
        let id = core.register(&label, &peft, 4000);
        let mut prng = Rng::new(700);
        let prompt: Arc<Vec<i32>> =
            Arc::new((0..prompt_len).map(|_| prng.below(cfg.vocab_size) as i32).collect());

        // Warmup sizes the lane pool and the [g, *] group scratch.
        let warm: Vec<Ticket> = (0..g).map(|_| Ticket::new(max_new)).collect();
        for t in &warm {
            submit_gen(&core, id, &prompt, max_new, t);
        }
        core.drain();

        let tickets: Vec<Ticket> = (0..total_gens).map(|_| Ticket::new(max_new)).collect();
        let sw = Stopwatch::start();
        for t in &tickets {
            submit_gen(&core, id, &prompt, max_new, t);
        }
        core.drain();
        let wall_secs = sw.secs();
        let mut tokens = 0u64;
        for t in &tickets {
            let (_, emitted) = t.wait().unwrap();
            tokens += emitted as u64;
        }
        let tokens_per_sec = tokens as f64 / wall_secs.max(1e-9);
        let stats = core.stats(id).unwrap();
        println!(
            "group {g:>2}: {total_gens} generations, {tokens:>6} tokens in \
             {wall_secs:>7.3}s = {tokens_per_sec:>9.1} tok/s \
             (mean group {:.2}, max {})",
            stats.mean_group_size(),
            stats.max_group_size
        );
        group_csv.push(format!("{g},{total_gens},{tokens},{wall_secs:.4},{tokens_per_sec:.2}"));
        group_results.push((g, tokens, wall_secs, tokens_per_sec));
    }
    write_csv(
        "decode_group_bench",
        "group,generations,tokens,wall_s,tokens_per_sec",
        &group_csv,
    );
    let gtps = |g: usize| -> f64 {
        group_results.iter().find(|c| c.0 == g).map(|c| c.3).unwrap_or(0.0)
    };
    let group_scaling = if gtps(1) > 0.0 { gtps(16) / gtps(1) } else { 0.0 };
    println!(
        "16-lane lockstep decode throughput = {group_scaling:.2}x the sequential baseline"
    );

    // --- Paged K/V at scale: 512 concurrent lanes in one group ---------
    // Drives GroupDecodeCache directly (the serve layer caps a group at
    // decode_batch) to pin the paged-memory claim: N concurrent
    // generations hold exactly ceil(len / PAGE_ROWS) pages per K/V
    // table — memory proportional to their ACTIVE tokens — where
    // monolithic per-lane rings would pre-commit N x max_seq rows.
    let n_lanes = 512usize;
    let lane_prompt_len = 6usize;
    let lane_new = if fast() { 2usize } else { 4 };
    let mut ws_lanes = Workspace::new();
    let backend_lanes = NativeBackend::for_adapter(&bb, &peft_for(0).1, 5000);
    let mut gc = GroupDecodeCache::new();
    let mut lrng = Rng::new(900);
    for _ in 0..n_lanes {
        let prompt: Vec<i32> =
            (0..lane_prompt_len).map(|_| lrng.below(cfg.vocab_size) as i32).collect();
        let stream = DecodeStream::new(&prompt);
        let mut kv = DecodeLane::new();
        kv.ensure(&backend_lanes.model, &mut ws_lanes);
        gc.join(kv, stream, Arc::new(prompt), lane_new, true);
    }
    let mut lane_outs: Vec<Vec<i32>> = vec![Vec::new(); n_lanes];
    let sw = Stopwatch::start();
    let all_done = gc
        .advance(&backend_lanes.model, usize::MAX, &mut ws_lanes, &mut lane_outs)
        .expect("lane positions stay under max_seq");
    let lanes_wall_secs = sw.secs();
    assert!(all_done, "every joined lane must run to completion");
    let lane_tokens: u64 = lane_outs.iter().map(|o| o.len() as u64).sum();
    let lanes_tps = lane_tokens as f64 / lanes_wall_secs.max(1e-9);

    // Peak page accounting: every lane still holds its pages here.
    let lane_len = lane_prompt_len + lane_new;
    let pages_per_table = lane_len.div_ceil(PAGE_ROWS);
    let expected_pages = n_lanes * cfg.n_layers * 2 * pages_per_table;
    let held_pages = ws_lanes.page_pool().outstanding() as usize;
    assert_eq!(
        held_pages, expected_pages,
        "paged K/V must hold exactly ceil(len/PAGE_ROWS) pages per table"
    );
    let page_bytes = PAGE_ROWS * cfg.d_model * std::mem::size_of::<f32>();
    let paged_kv_mib = (held_pages * page_bytes) as f64 / (1024.0 * 1024.0);
    let monolithic_rows = n_lanes * cfg.n_layers * 2 * cfg.max_seq;
    let monolithic_kv_mib = (monolithic_rows * cfg.d_model * std::mem::size_of::<f32>())
        as f64
        / (1024.0 * 1024.0);
    let kv_ratio = paged_kv_mib / monolithic_kv_mib;
    println!(
        "concurrent lanes: {n_lanes} generations, {lane_tokens} tokens in \
         {lanes_wall_secs:.3}s = {lanes_tps:.1} tok/s; {held_pages} pages = \
         {paged_kv_mib:.1} MiB paged vs {monolithic_kv_mib:.1} MiB monolithic \
         ({kv_ratio:.3}x)"
    );
    write_csv(
        "decode_lanes_bench",
        "lanes,tokens,wall_s,tokens_per_sec,pages,paged_mib,monolithic_mib",
        &[format!(
            "{n_lanes},{lane_tokens},{lanes_wall_secs:.4},{lanes_tps:.2},\
             {held_pages},{paged_kv_mib:.1},{monolithic_kv_mib:.1}"
        )],
    );
    // Tear-down recycles every page: the pool must account for all of
    // them (a leak or double-free trips the counters / the pool panic).
    while let Some((mut kv, _stream, done)) = gc.detach_first() {
        assert!(done, "detach order is join order and every lane finished");
        kv.free_pages(&mut ws_lanes);
    }
    gc.release(&mut ws_lanes);
    assert_eq!(
        ws_lanes.page_pool().outstanding(),
        0,
        "all K/V pages must return to the pool at tear-down"
    );

    // --- TTFT for a mid-flight joiner: chunked vs tokenwise prefill ----
    // A lane with a long prompt joins a group of already-decoding lanes;
    // count the lockstep steps until its first emitted token. Chunked
    // prefill reaches it in ceil(prompt / chunk) steps, the tokenwise
    // schedule in `prompt` steps — both exact, both asserted, so the
    // gate on the chunked key is machine-independent.
    let join_prompt_len = 32usize;
    let ttft_steps = |chunk: usize, ws: &mut Workspace| -> usize {
        let mut gc = GroupDecodeCache::new();
        gc.set_prefill_chunk(chunk);
        let n_decoding = 4usize;
        let mut jrng = Rng::new(901);
        for _ in 0..n_decoding {
            let prompt: Vec<i32> =
                (0..2).map(|_| jrng.below(cfg.vocab_size) as i32).collect();
            let mut kv = DecodeLane::new();
            kv.ensure(&backend_lanes.model, ws);
            let stream = DecodeStream::new(&prompt);
            gc.join(kv, stream, Arc::new(prompt), join_prompt_len + 8, true);
        }
        let jprompt: Vec<i32> =
            (0..join_prompt_len).map(|_| jrng.below(cfg.vocab_size) as i32).collect();
        let mut kv = DecodeLane::new();
        kv.ensure(&backend_lanes.model, ws);
        let stream = DecodeStream::new(&jprompt);
        let ji = gc.join(kv, stream, Arc::new(jprompt), 2, true);
        let mut outs: Vec<Vec<i32>> = vec![Vec::new(); n_decoding + 1];
        let mut steps = 0usize;
        while outs[ji].is_empty() {
            gc.advance(&backend_lanes.model, 1, ws, &mut outs)
                .expect("joiner prompt fits max_seq");
            steps += 1;
            assert!(steps <= 2 * join_prompt_len, "joiner must reach its first token");
        }
        gc.release(ws);
        steps
    };
    let ttft_chunked = ttft_steps(native::DEFAULT_PREFILL_CHUNK, &mut ws_lanes);
    let ttft_tokenwise = ttft_steps(1, &mut ws_lanes);
    assert_eq!(
        ttft_chunked,
        join_prompt_len.div_ceil(native::DEFAULT_PREFILL_CHUNK),
        "chunked prefill reaches first token in ceil(prompt/chunk) group steps"
    );
    assert_eq!(
        ttft_tokenwise, join_prompt_len,
        "tokenwise schedule needs one group step per prompt token"
    );
    println!(
        "joiner TTFT ({join_prompt_len}-token prompt): {ttft_chunked} group steps \
         chunked (chunk {}) vs {ttft_tokenwise} tokenwise",
        native::DEFAULT_PREFILL_CHUNK
    );

    let json = Json::obj(vec![
        (
            "workload",
            Json::Str(format!(
                "decoder_small; psoft/lora/oftv2/boft mix on Q,V; greedy; \
                 prompt {prompt_len} x {max_new} new tokens"
            )),
        ),
        ("workers", Json::Num(workers as f64)),
        ("generations_per_adapter", Json::Num(gens_per_adapter as f64)),
        ("fast_mode", Json::Bool(fast())),
        ("prefill_ms", Json::Num(prefill_ms)),
        ("per_token_ms", Json::Num(per_token_ms)),
        ("prefill_tokenwise_ms", Json::Num(prefill_tokenwise_ms)),
        ("prefill_batched_ms", Json::Num(prefill_batched_ms)),
        ("prefill_batch_speedup", Json::Num(prefill_batch_speedup)),
        (
            "configs",
            Json::Arr(
                results
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("adapters", Json::Num(c.adapters as f64)),
                            ("generations", Json::Num(c.generations as f64)),
                            ("tokens", Json::Num(c.tokens as f64)),
                            ("wall_secs", Json::Num(c.wall_secs)),
                            ("tokens_per_sec", Json::Num(c.tokens_per_sec)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("tokens_per_sec_1", Json::Num(tps_at(1))),
        ("tokens_per_sec_16", Json::Num(tps_at(16))),
        ("scaling_16x_over_1x", Json::Num(scaling)),
        (
            "group_configs",
            Json::Arr(
                group_results
                    .iter()
                    .map(|&(g, tokens, wall_secs, tps)| {
                        Json::obj(vec![
                            ("group", Json::Num(g as f64)),
                            ("generations", Json::Num(total_gens as f64)),
                            ("tokens", Json::Num(tokens as f64)),
                            ("wall_secs", Json::Num(wall_secs)),
                            ("tokens_per_sec", Json::Num(tps)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("tokens_per_sec_g1", Json::Num(gtps(1))),
        ("tokens_per_sec_g16", Json::Num(gtps(16))),
        ("group_scaling_16x_over_1x", Json::Num(group_scaling)),
        ("concurrent_lanes", Json::Num(n_lanes as f64)),
        ("concurrent_lanes_tokens", Json::Num(lane_tokens as f64)),
        ("concurrent_lanes_wall_secs", Json::Num(lanes_wall_secs)),
        ("concurrent_lanes_tokens_per_sec", Json::Num(lanes_tps)),
        ("concurrent_lane_pages", Json::Num(held_pages as f64)),
        ("paged_kv_mib", Json::Num(paged_kv_mib)),
        ("monolithic_kv_mib", Json::Num(monolithic_kv_mib)),
        ("paged_over_monolithic_kv_ratio", Json::Num(kv_ratio)),
        ("ttft_group_steps_chunked", Json::Num(ttft_chunked as f64)),
        ("ttft_group_steps_tokenwise", Json::Num(ttft_tokenwise as f64)),
    ]);
    std::fs::write("BENCH_decode.json", json.dump_pretty()).expect("write BENCH_decode.json");
    eprintln!("wrote BENCH_decode.json");
}
