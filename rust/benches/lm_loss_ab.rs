//! §Perf A/B microbench: the decoder LM-loss hot path, scalar loops vs
//! the gather+matmul rewrite (EXPERIMENTS.md §Perf).
// Style allowances shared by the bench/test crates: index loops mirror
// the math notation, and config structs are built default-then-override.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::field_reassign_with_default)]

// quick honest measurement: decoder train step + isolated scalar-vs-matmul LM loss
use psoft::bench::time_ms;
use psoft::config::*;
use psoft::linalg::{matmul, matmul_nt, matmul_tn, Mat};
use psoft::model::native::{Batch, Target};
use psoft::model::{Backbone, NativeModel};
use psoft::runtime::{Backend, Hyper, NativeBackend};
use psoft::util::rng::Rng;

fn main() {
    let cfg = ModelConfig::decoder_small();
    let mut rng = Rng::new(1);
    let bb = Backbone::random(&cfg, &mut rng);
    let mut p = PeftConfig::new(MethodKind::Psoft, 32);
    p.modules = cfg.modules();
    let model = NativeModel::from_backbone(&bb, &p, &mut rng);
    let mut be = NativeBackend::new(model);
    let (bsz, seq) = (16usize, 32usize);
    let tokens: Vec<i32> = (0..bsz * seq).map(|_| rng.below(cfg.vocab_size) as i32).collect();
    let mut mask = vec![0.0f32; bsz * seq];
    for b in 0..bsz {
        for s in seq / 2..seq {
            mask[b * seq + s] = 1.0;
        }
    }
    let batch = Batch {
        batch: bsz,
        seq,
        tokens: tokens.clone(),
        pad: vec![1.0; bsz * seq],
        target: Target::LmMask(mask),
    };
    let hyper = Hyper::default();
    let mut ws = psoft::linalg::Workspace::new();
    let t = time_ms(5, || {
        be.train_step(&batch, &hyper, &mut ws).unwrap();
    });
    println!("decoder train_step (matmul LM loss): {t:.1} ms");

    // Isolated LM-loss cost comparison at the same shape.
    let d = cfg.d_model;
    let v = cfg.vocab_size;
    let m = bsz * seq / 2;
    let hidden = Mat::randn(m, d, 1.0, &mut rng);
    let lm = Mat::randn(d, v, 0.05, &mut rng);
    let t_mat = time_ms(5, || {
        let logits = matmul(&hidden, &lm);
        let dl = logits.clone();
        let _dlm = matmul_tn(&hidden, &dl);
        let _dh = matmul_nt(&dl, &lm);
    });
    let t_scalar = time_ms(3, || {
        let mut d_lm = Mat::zeros(d, v);
        let mut acc = 0.0f32;
        for t in 0..m {
            let hrow = hidden.row(t);
            let mut logits = vec![0.0f32; v];
            for i in 0..d {
                let hv = hrow[i];
                let lrow = lm.row(i);
                for (lo, &lv) in logits.iter_mut().zip(lrow) {
                    *lo += hv * lv;
                }
            }
            for j in 0..v {
                acc += logits[j];
                for i in 0..d {
                    d_lm[(i, j)] += logits[j] * hrow[i];
                }
            }
        }
        std::hint::black_box((acc, d_lm));
    });
    println!(
        "LM loss fwd+bwd isolated: scalar {t_scalar:.1} ms vs matmul {t_mat:.1} ms ({:.1}x)",
        t_scalar / t_mat
    );
}
