//! SLO bench: open-loop, trace-driven fleet serving on the decoder
//! backbone — hundreds of adapters under Zipf popularity competing for
//! `max_resident` slots, Poisson arrivals at a fixed offered rate, and
//! heavy-tailed prompt/output lengths (`runtime::loadgen`). Unlike the
//! closed-loop `serve` bench, the client never slows down with the
//! server, so queueing, shedding, and reload-lane stalls become visible
//! in the tail percentiles.
//!
//! Reports streaming TTFT p50/p95/p99 and p99 per-token latency from the
//! per-adapter quantile sketches — fleet-wide AND split by tier
//! (interactive tier 0 vs batch tier ≥ 1, from the per-tier sketches the
//! serve layer records), so the interactive tail is gated on its own
//! `ttft_interactive_ms` key and never averaged against batch traffic —
//! plus admission outcome counts, chunked-prefill token counts, and the
//! process RSS. Emits `BENCH_slo.json` for the CI bench gate
//! (`tools/bench_gate --foreach ttft_ms ...`). `PSOFT_BENCH_FAST=1`
//! shrinks the trace to CI-smoke size; the fleet shape is overridable:
//!
//! - `PSOFT_SLO_ADAPTERS`      fleet size (default 200; fast 32)
//! - `PSOFT_SLO_MAX_RESIDENT`  resident-slot budget (default 8)
//! - `PSOFT_SLO_REQUESTS`      trace length (default 1500; fast 240)
//! - `PSOFT_SLO_RATE`          offered load, req/s (default 250; fast 120)
//! - `PSOFT_SLO_OUT`           output JSON path (default BENCH_slo.json)
//! - `PSOFT_SLO_MAX_RSS_MIB`   if set, assert RSS stays below this bound

// Style allowances shared by the bench/test crates: index loops mirror
// the math notation, and config structs are built default-then-override.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::field_reassign_with_default)]

use psoft::bench::{bench_decoder, write_csv};
use psoft::config::{MethodKind, ModuleKind, PeftConfig};
use psoft::model::Backbone;
use psoft::peft::AdapterId;
use psoft::runtime::loadgen::{LengthDist, LoadSpec, Trace};
use psoft::runtime::serve::{
    Admission, Request, ServeCore, ServeError, ServeOptions, SubmitOptions, Ticket,
};
use psoft::util::json::Json;
use psoft::util::rng::Rng;
use psoft::util::stats::{resident_set_bytes, QuantileSketch};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn fast() -> bool {
    std::env::var("PSOFT_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Fleet mix: mostly cheap LoRA adapters, with a PSOFT adapter every
/// 16th registration so the async reload lane pays real SVD
/// re-derivations under churn.
fn peft_for(i: usize) -> (String, PeftConfig) {
    if i % 16 == 0 {
        let mut p =
            PeftConfig::new(MethodKind::Psoft, 4).with_modules(vec![ModuleKind::Q]);
        p.svd_n_iter = Some(1);
        (format!("psoft_{i}"), p)
    } else {
        let p = PeftConfig::new(MethodKind::Lora, 2).with_modules(vec![ModuleKind::Q]);
        (format!("lora_{i}"), p)
    }
}

fn main() {
    let cfg = bench_decoder();
    let adapters = env_usize("PSOFT_SLO_ADAPTERS", if fast() { 32 } else { 200 });
    let max_resident = env_usize("PSOFT_SLO_MAX_RESIDENT", 8);
    let n_requests = env_usize("PSOFT_SLO_REQUESTS", if fast() { 240 } else { 1500 });
    let rate_rps = env_f64("PSOFT_SLO_RATE", if fast() { 120.0 } else { 250.0 });
    let out_path =
        std::env::var("PSOFT_SLO_OUT").unwrap_or_else(|_| "BENCH_slo.json".to_string());
    let workers = psoft::util::threadpool::default_parallelism().min(8);

    let spec = LoadSpec {
        adapters,
        rate_rps,
        n_requests,
        zipf_s: 1.1,
        prompt_len: LengthDist::new(2, 24, 1.2),
        output_len: LengthDist::new(1, 8, 1.3),
        interactive_share: 0.5,
        seed: 42,
    };
    let trace = Trace::generate(&spec);
    println!(
        "=== slo bench: {adapters} adapters (max_resident {max_resident}), \
         {n_requests} open-loop requests at {rate_rps:.0} req/s over {workers} workers ===",
    );

    let mut rng = Rng::new(0x510_BE0C);
    let bb = Arc::new(Backbone::random(&cfg, &mut rng));
    let spill_dir =
        std::env::temp_dir().join(format!("psoft_slo_spill_{}", std::process::id()));
    let opts = ServeOptions {
        workers,
        queue_cap: 64,
        burst: 2,
        decode_batch: 4,
        max_resident,
        spill_dir: Some(spill_dir.clone()),
        tier_weights: vec![3, 1],
        ..Default::default()
    };
    let core = ServeCore::new(Arc::clone(&bb), opts);
    let ids: Vec<AdapterId> = (0..adapters)
        .map(|i| {
            let (label, peft) = peft_for(i);
            core.register(&label, &peft, 9000 + i as u64)
        })
        .collect();
    println!(
        "registered {} adapters, {} resident after fleet spill-down",
        ids.len(),
        core.num_resident()
    );

    // Materialize every prompt before the clock starts; the replay loop
    // itself only Arc-clones.
    let prompts: Vec<Arc<Vec<i32>>> = trace
        .arrivals
        .iter()
        .enumerate()
        .map(|(i, a)| {
            let mut prng = Rng::new(0x9E37 ^ i as u64);
            Arc::new(
                (0..a.prompt_len).map(|_| prng.below(cfg.vocab_size) as i32).collect(),
            )
        })
        .collect();
    let tickets: Vec<Ticket> =
        trace.arrivals.iter().map(|a| Ticket::new(a.max_new_tokens)).collect();

    // Open-loop replay: the trace clock, not request completion, decides
    // when the next submit fires. Interactive arrivals (tier 0) carry a
    // deadline; batch arrivals ride the low weighted-fair tier.
    let mut admitted: Vec<usize> = Vec::with_capacity(n_requests);
    let mut rejected = 0u64;
    let mut shed_at_submit = 0u64;
    let start = Instant::now();
    for (i, a) in trace.arrivals.iter().enumerate() {
        let now = start.elapsed();
        if a.at > now {
            std::thread::sleep(a.at - now);
        }
        let mut sopts = SubmitOptions::new().with_priority(a.tier);
        if a.tier == 0 {
            sopts = sopts.with_deadline(Duration::from_secs(30));
        }
        let req = Request::Generate {
            prompt: Arc::clone(&prompts[i]),
            max_new_tokens: a.max_new_tokens,
            greedy: true,
        };
        match core.submit(ids[a.adapter], req, &tickets[i], sopts) {
            Admission::Admitted => admitted.push(i),
            Admission::Rejected(_) => rejected += 1,
            Admission::Shed(_) => shed_at_submit += 1,
        }
    }
    core.drain();
    let wall_secs = start.elapsed().as_secs_f64();

    let mut completed = 0u64;
    let mut shed_in_queue = 0u64;
    let mut failed = 0u64;
    for &i in &admitted {
        match tickets[i].wait() {
            Ok(_) => completed += 1,
            Err(ServeError::Shed(_)) => shed_in_queue += 1,
            Err(_) => failed += 1,
        }
    }
    let shed = shed_at_submit + shed_in_queue;
    let submitted = trace.arrivals.len() as u64;
    let shed_rate = shed as f64 / submitted as f64;

    // Fleet-wide tail latency: merge the per-adapter streaming sketches,
    // combined and per tier (interactive = tier 0, batch = tier >= 1).
    let mut ttft = QuantileSketch::default();
    let mut ttft_tiered = [QuantileSketch::default(); 2];
    let mut tok = QuantileSketch::default();
    let mut tokens_generated = 0u64;
    let mut prefill_chunks = 0u64;
    let mut prefill_tokens = 0u64;
    for (_, _, s) in core.adapters() {
        ttft.merge(&s.ttft);
        ttft_tiered[0].merge(&s.ttft_tiered[0]);
        ttft_tiered[1].merge(&s.ttft_tiered[1]);
        tok.merge(&s.tok_latency);
        tokens_generated += s.tokens_generated;
        prefill_chunks += s.prefill_chunks;
        prefill_tokens += s.prefill_tokens;
    }
    let panics = core.worker_panics();
    let rss_mib =
        resident_set_bytes().map(|b| b as f64 / (1024.0 * 1024.0)).unwrap_or(0.0);

    assert_eq!(panics, 0, "open-loop smoke must not panic any worker");
    assert_eq!(failed, 0, "admitted requests must complete or shed, never error");
    assert!(completed > 0, "the trace must complete some requests");
    assert!(ttft.count() > 0, "TTFT sketch must have samples");
    assert!(
        ttft_tiered[0].count() > 0,
        "the interactive tier must complete some requests (it rides the high \
         weighted-fair weight)"
    );
    let max_rss = env_f64("PSOFT_SLO_MAX_RSS_MIB", 0.0);
    if max_rss > 0.0 {
        assert!(
            rss_mib > 0.0 && rss_mib < max_rss,
            "RSS {rss_mib:.0} MiB breaches the {max_rss:.0} MiB bound"
        );
    }

    let p = |s: &QuantileSketch, q: f64| s.quantile(q) / 1e6;
    println!(
        "completed {completed}/{submitted} ({rejected} rejected, {shed} shed) in \
         {wall_secs:.2}s — {tokens_generated} tokens, offered {:.1} req/s",
        trace.offered_rps()
    );
    println!(
        "TTFT p50/p95/p99 = {:.2}/{:.2}/{:.2} ms, per-token p99 = {:.3} ms, \
         rss {rss_mib:.0} MiB",
        p(&ttft, 0.5),
        p(&ttft, 0.95),
        p(&ttft, 0.99),
        p(&tok, 0.99)
    );
    println!(
        "TTFT by tier: interactive p50/p99 = {:.2}/{:.2} ms ({} samples), \
         batch p50/p99 = {:.2}/{:.2} ms ({} samples); prefill \
         {prefill_tokens} prompt tokens in {prefill_chunks} chunks",
        p(&ttft_tiered[0], 0.5),
        p(&ttft_tiered[0], 0.99),
        ttft_tiered[0].count(),
        p(&ttft_tiered[1], 0.5),
        p(&ttft_tiered[1], 0.99),
        ttft_tiered[1].count(),
    );

    write_csv(
        "slo_bench",
        "adapters,max_resident,requests,completed,rejected,shed,offered_rps,\
         ttft_p50_ms,ttft_p95_ms,ttft_p99_ms,ttft_interactive_p99_ms,\
         ttft_batch_p99_ms,tok_p99_ms,rss_mib",
        &[format!(
            "{adapters},{max_resident},{submitted},{completed},{rejected},{shed},\
             {:.2},{:.3},{:.3},{:.3},{:.3},{:.3},{:.4},{rss_mib:.0}",
            trace.offered_rps(),
            p(&ttft, 0.5),
            p(&ttft, 0.95),
            p(&ttft, 0.99),
            p(&ttft_tiered[0], 0.99),
            p(&ttft_tiered[1], 0.99),
            p(&tok, 0.99)
        )],
    );

    let json = Json::obj(vec![
        (
            "note",
            Json::Str(
                "committed baseline holds conservative ceilings (lower-is-better); \
                 refresh with bench_gate --update-baselines on a quiet machine"
                    .to_string(),
            ),
        ),
        (
            "workload",
            Json::Str(format!(
                "decoder_small; {adapters}-adapter Zipf(s=1.1) fleet, max_resident \
                 {max_resident}; Poisson {rate_rps:.0} req/s x {n_requests}; \
                 bounded-Pareto prompt 2..24 / output 1..8; 50% interactive tier"
            )),
        ),
        ("fast_mode", Json::Bool(fast())),
        ("adapters", Json::Num(adapters as f64)),
        ("max_resident", Json::Num(max_resident as f64)),
        ("workers", Json::Num(workers as f64)),
        ("offered_rps", Json::Num(trace.offered_rps())),
        ("wall_secs", Json::Num(wall_secs)),
        ("submitted", Json::Num(submitted as f64)),
        ("completed", Json::Num(completed as f64)),
        ("rejected", Json::Num(rejected as f64)),
        ("shed", Json::Num(shed as f64)),
        ("shed_rate", Json::Num(shed_rate)),
        ("tokens_generated", Json::Num(tokens_generated as f64)),
        (
            "ttft_ms",
            Json::obj(vec![
                ("p50", Json::Num(p(&ttft, 0.5))),
                ("p95", Json::Num(p(&ttft, 0.95))),
                ("p99", Json::Num(p(&ttft, 0.99))),
            ]),
        ),
        // Tier-conditional TTFT: the interactive tail is gated on its own
        // keys (deadline-carrying tier-0 traffic must never hide behind a
        // fleet-wide percentile that batch traffic drags up or down).
        (
            "ttft_interactive_ms",
            Json::obj(vec![
                ("p50", Json::Num(p(&ttft_tiered[0], 0.5))),
                ("p95", Json::Num(p(&ttft_tiered[0], 0.95))),
                ("p99", Json::Num(p(&ttft_tiered[0], 0.99))),
            ]),
        ),
        (
            "ttft_batch_ms",
            Json::obj(vec![
                ("p50", Json::Num(p(&ttft_tiered[1], 0.5))),
                ("p95", Json::Num(p(&ttft_tiered[1], 0.95))),
                ("p99", Json::Num(p(&ttft_tiered[1], 0.99))),
            ]),
        ),
        ("ttft_interactive_samples", Json::Num(ttft_tiered[0].count() as f64)),
        ("ttft_batch_samples", Json::Num(ttft_tiered[1].count() as f64)),
        ("per_token_ms", Json::obj(vec![("p99", Json::Num(p(&tok, 0.99)))])),
        ("prefill_chunks", Json::Num(prefill_chunks as f64)),
        ("prefill_tokens", Json::Num(prefill_tokens as f64)),
        ("worker_panics", Json::Num(panics as f64)),
        ("rss_mib", Json::Num(rss_mib)),
    ]);
    std::fs::write(&out_path, json.dump_pretty()).expect("write BENCH_slo.json");
    eprintln!("wrote {out_path}");
    drop(core);
    std::fs::remove_dir_all(&spill_dir).ok();
}
