//! Main-result benches: regenerate Tables 2–5 at CPU scale.
//!
//! Pretrains (or loads cached) backbones, runs the task × method × seed
//! grids through the coordinator, and prints the paper-style tables. The
//! assertion targets are the *shape* claims (DESIGN.md §6): PSOFT completes
//! everywhere, with parameter counts far below the LoRA-family at matched
//! ranks, and average metric within noise of the best baseline.
//!
//! Environment knobs: PSOFT_BENCH_FAST=1 shrinks the grids (CI smoke).

// Style allowances shared by the bench/test crates: index loops mirror
// the math notation, and config structs are built default-then-override.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::field_reassign_with_default)]

use psoft::bench::{bench_decoder, bench_encoder, bench_vit, pretrained_backbone};
use psoft::config::{DataConfig, MethodKind, PeftConfig, TrainConfig};
use psoft::coordinator::{aggregate, grid, report, DeviceBudget, SuiteRunner};
use psoft::data::suite_tasks;
use psoft::util::stats::{human_duration, Stopwatch};
use std::sync::Arc;

fn fast() -> bool {
    std::env::var("PSOFT_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

fn main() {
    let sw = Stopwatch::start();
    table2_glue();
    table3_vtab();
    table4_mathqa();
    table5_commonsense();
    eprintln!("paper_tables total wall: {}", human_duration(sw.secs()));
}

fn methods_encoder() -> Vec<(String, PeftConfig)> {
    let mk = |m: MethodKind, r: usize| (format!("{}_r{r}", m.name()), PeftConfig::new(m, r));
    let mut v = vec![
        mk(MethodKind::Psoft, 46),
        mk(MethodKind::Lora, 8),
        mk(MethodKind::Pissa, 8),
        mk(MethodKind::Dora, 8),
        mk(MethodKind::LoraXs, 46),
        mk(MethodKind::OftV2, 8),
        mk(MethodKind::Boft, 8),
        mk(MethodKind::Goft, 1),
    ];
    if fast() {
        v.truncate(3);
    }
    v
}

fn table2_glue() {
    println!("\n=== Table 2 (sim): GLUE suite on the pretrained encoder ===");
    let cfg = bench_encoder();
    let bb = pretrained_backbone(&cfg, "enc", 200);
    let tasks: Vec<DataConfig> = suite_tasks("glue")
        .into_iter()
        .map(|t| {
            let mut d = DataConfig::new("glue", t);
            d.n_train = if fast() { 64 } else { 200 };
            d.n_val = 64;
            d.n_test = 64;
            d.seq_len = 24;
            d
        })
        .collect();
    let mut methods = methods_encoder();
    for (_, p) in methods.iter_mut() {
        p.modules = cfg.modules();
        p.oft_block_size = 32;
    }
    let mut tc = TrainConfig::default();
    tc.epochs = if fast() { 1 } else { 4 };
    tc.batch_size = 32;
    tc.lr = 2e-3;
    tc.head_lr = 2e-3;
    let seeds: Vec<u64> = if fast() { vec![1] } else { vec![1, 2] };

    let jobs = grid(&tasks, &methods, &tc, &seeds);
    let runner = Arc::new(SuiteRunner::new(bb, DeviceBudget::unlimited()));
    let results = runner.run_all(jobs, psoft::util::threadpool::default_parallelism());
    let cells = aggregate(&results);
    let table = report::Table::from_cells("Table 2 (sim): GLUE", &suite_tasks("glue"), &cells);
    println!("{}", table.to_markdown());
    report::write_bundle(std::path::Path::new("reports"), "table2_glue", &table).unwrap();

    // Shape assertions: PSOFT params ≪ LoRA params; PSOFT avg within 15
    // points of the best.
    let psoft_row = table.rows.iter().find(|r| r.label.starts_with("psoft")).unwrap();
    let lora_row = table.rows.iter().find(|r| r.label.starts_with("lora_r8")).unwrap();
    assert!(psoft_row.params * 2 < lora_row.params, "PSOFT parameter advantage");
    let best = table.rows.iter().map(|r| r.avg).fold(f64::NAN, f64::max);
    assert!(psoft_row.avg > best - 15.0, "PSOFT avg {} vs best {}", psoft_row.avg, best);
}

fn table3_vtab() {
    println!("\n=== Table 3 (sim): VTAB suite on the pretrained ViT-sim ===");
    let cfg = bench_vit();
    let bb = pretrained_backbone(&cfg, "vit", 200);
    let all = suite_tasks("vtab");
    let picked: Vec<&str> = if fast() { all[..3].to_vec() } else { all.clone() };
    let tasks: Vec<DataConfig> = picked
        .iter()
        .map(|t| {
            let mut d = DataConfig::new("vtab", t);
            d.n_train = if fast() { 64 } else { 200 };
            d.n_val = 50;
            d.n_test = 50;
            d.seq_len = 24;
            d
        })
        .collect();
    let mk = |m: MethodKind, r: usize| {
        let mut p = PeftConfig::new(m, r);
        p.modules = cfg.modules();
        (format!("{}_r{r}", m.name()), p)
    };
    let methods =
        vec![mk(MethodKind::Psoft, 46), mk(MethodKind::Lora, 8), mk(MethodKind::LoraXs, 46)];
    let mut tc = TrainConfig::default();
    tc.epochs = if fast() { 1 } else { 4 };
    tc.batch_size = 32;
    tc.lr = 2e-3;
    tc.head_lr = 5e-3;
    let jobs = grid(&tasks, &methods, &tc, &[1]);
    let runner = Arc::new(SuiteRunner::new(bb, DeviceBudget::unlimited()));
    let results = runner.run_all(jobs, psoft::util::threadpool::default_parallelism());
    let cells = aggregate(&results);
    let table = report::Table::from_cells("Table 3 (sim): VTAB", &picked, &cells);
    println!("{}", table.to_markdown());
    report::write_bundle(std::path::Path::new("reports"), "table3_vtab", &table).unwrap();
}

fn decoder_table(title: &str, file: &str, suite: &str, tasks_pick: &[&str]) {
    let cfg = bench_decoder();
    let bb = pretrained_backbone(&cfg, "dec", 200);
    let tasks: Vec<DataConfig> = tasks_pick
        .iter()
        .map(|t| {
            let mut d = DataConfig::new(suite, t);
            d.n_train = if fast() { 48 } else { 160 };
            d.n_val = 48;
            d.n_test = 48;
            d.seq_len = 32;
            d
        })
        .collect();
    let mk = |m: MethodKind, r: usize| {
        let mut p = PeftConfig::new(m, r);
        p.modules = cfg.modules();
        (format!("{}_r{r}", m.name()), p)
    };
    let methods = vec![
        mk(MethodKind::Psoft, 32),
        mk(MethodKind::Lora, 8),
        mk(MethodKind::Pissa, 8),
        mk(MethodKind::OftV2, 8),
    ];
    let mut tc = TrainConfig::default();
    tc.epochs = if fast() { 1 } else { 3 };
    tc.batch_size = 16;
    tc.lr = 2e-3;
    tc.head_lr = 2e-3;
    let jobs = grid(&tasks, &methods, &tc, &[1]);
    let runner = Arc::new(SuiteRunner::new(bb, DeviceBudget::unlimited()));
    let results = runner.run_all(jobs, psoft::util::threadpool::default_parallelism());
    let cells = aggregate(&results);
    let table = report::Table::from_cells(title, tasks_pick, &cells);
    println!("{}", table.to_markdown());
    report::write_bundle(std::path::Path::new("reports"), file, &table).unwrap();
}

fn table4_mathqa() {
    println!("\n=== Table 4 (sim): GSM-8K / MATH on the pretrained decoder ===");
    decoder_table("Table 4 (sim): MathQA", "table4_mathqa", "mathqa", &["gsm8k", "math"]);
}

fn table5_commonsense() {
    println!("\n=== Table 5 (sim): commonsense reasoning ×8 ===");
    let all = suite_tasks("commonsense");
    let picked: Vec<&str> = if fast() { all[..2].to_vec() } else { all };
    decoder_table("Table 5 (sim): Commonsense", "table5_commonsense", "commonsense", &picked);
}
