//! Performance benches: Tables 19/20 (single linear layer / transformer
//! block FP+BP time and live-activation accounting per method), Tables
//! 21/22 (whole-model projections vs sequence length / batch), Fig 4b
//! (training speed per method), plus substrate microbenches (matmul,
//! Cayley–Neumann, SVD) used by the §Perf iteration log.

// Style allowances shared by the bench/test crates: index loops mirror
// the math notation, and config structs are built default-then-override.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::field_reassign_with_default)]

use psoft::bench::{bench_encoder, pretrained_backbone, time_ms, write_csv};
use psoft::config::{MethodKind, ModelConfig, PeftConfig};
use psoft::linalg::{matmul, svd, DMat, Mat, Workspace};
use psoft::memmodel::{activation::ActShape, peak_memory_estimate, PaperModel};
use psoft::model::native::{self, Batch, Target};
use psoft::model::NativeModel;
use psoft::peft::build_adapter;
use psoft::runtime::{Backend, Hyper, NativeBackend};
use psoft::util::rng::Rng;
use psoft::util::stats::Stopwatch;

fn fast() -> bool {
    std::env::var("PSOFT_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// `PSOFT_BENCH_ONLY=hotpath` (etc.) restricts the run to one section —
/// the CI smoke job runs only the hot-path anchor against the committed
/// `BENCH_hotpath.json` baseline.
fn enabled(name: &str) -> bool {
    match std::env::var("PSOFT_BENCH_ONLY") {
        Ok(only) => only == name,
        Err(_) => true,
    }
}

fn main() {
    if enabled("hotpath") {
        hotpath_bench();
    }
    if enabled("micro") {
        micro_substrates();
    }
    if enabled("table19") {
        table19_single_layer();
    }
    if enabled("table20") {
        table20_block();
    }
    if enabled("memory") {
        table21_22_model_memory();
    }
    if enabled("fig4b") {
        fig4b_training_speed();
    }
}

/// Peak resident set size in bytes (Linux VmHWM; 0 when unavailable).
fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else { return 0 };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// The perf-trajectory anchor: steady-state native training step on the
/// standard encoder workload, with a per-phase ns/step breakdown. Emits
/// `BENCH_hotpath.json` so subsequent PRs have a baseline to beat.
fn hotpath_bench() {
    println!("\n=== hot path: steady-state native train step ===");
    let cfg: ModelConfig = bench_encoder();
    let mut rng = Rng::new(90);
    let bb = psoft::model::Backbone::random(&cfg, &mut rng);
    let mut peft = PeftConfig::new(MethodKind::Psoft, 32);
    peft.modules = cfg.modules();
    let model = NativeModel::from_backbone(&bb, &peft, &mut rng);
    let mut be = NativeBackend::new(model);
    let (bsz, seq) = (16usize, 24usize);
    let tokens: Vec<i32> = (0..bsz * seq).map(|_| rng.below(cfg.vocab_size) as i32).collect();
    let labels: Vec<usize> = (0..bsz).map(|b| (tokens[b * seq] as usize) % 2).collect();
    let batch = Batch {
        batch: bsz,
        seq,
        tokens,
        pad: vec![1.0; bsz * seq],
        target: Target::Class(labels),
    };
    let hyper = Hyper::default();
    let mut ws = Workspace::new();

    // Warm the step buffers, the workspace pool, and the persistent
    // compute pool (built lazily at the first large matmul).
    for _ in 0..3 {
        be.step_core(&batch, &hyper, &mut ws);
    }
    let misses_before = ws.misses();
    let spawns_before = psoft::util::threadpool::thread_spawn_count();

    let steps = if fast() { 10 } else { 50 };
    // Phase A: forward + loss only.
    let sw = Stopwatch::start();
    for _ in 0..steps {
        native::evaluate_into(&be.model, &batch, &mut be.bufs, &mut ws);
    }
    let fwd_ns = sw.secs() * 1e9 / steps as f64;
    // Phase B: forward + backward (gradients).
    let sw = Stopwatch::start();
    for _ in 0..steps {
        native::train_grads_into(&be.model, &batch, 0.0, &mut be.bufs, &mut ws);
    }
    let grads_ns = sw.secs() * 1e9 / steps as f64;
    // Phase C: the full optimizer step.
    let sw = Stopwatch::start();
    for _ in 0..steps {
        be.step_core(&batch, &hyper, &mut ws);
    }
    let step_ns = sw.secs() * 1e9 / steps as f64;

    let backward_ns = (grads_ns - fwd_ns).max(0.0);
    let optimizer_ns = (step_ns - grads_ns).max(0.0);
    let steps_per_sec = 1e9 / step_ns;
    let pool_misses_after_warmup = ws.misses() - misses_before;
    // Warm phases must run entirely on the persistent compute pool: any
    // non-zero delta here means a kernel regressed to spawn-per-call.
    let thread_spawns = psoft::util::threadpool::thread_spawn_count() - spawns_before;
    let rss = peak_rss_bytes();

    // Pool-speedup probe: the identical accumulate-into-slice matmul via
    // the retained seed kernel (scoped spawns per call) vs the persistent
    // pool + tiled kernels. The shape sits just above the parallel
    // thresholds, where per-call spawn overhead is most visible.
    let pa = Mat::randn(192, 128, 1.0, &mut rng);
    let pb = Mat::randn(128, 192, 1.0, &mut rng);
    let mut pc = vec![0.0f32; 192 * 192];
    let iters = if fast() { 20 } else { 50 };
    psoft::linalg::matmul::matmul_acc_slice_spawn_ref(&pa, &pb, &mut pc);
    psoft::linalg::matmul_acc_slice(&pa, &pb, &mut pc);
    let sw = Stopwatch::start();
    for _ in 0..iters {
        pc.fill(0.0);
        psoft::linalg::matmul::matmul_acc_slice_spawn_ref(&pa, &pb, &mut pc);
    }
    let seed_mm_ns = sw.secs() * 1e9 / iters as f64;
    let sw = Stopwatch::start();
    for _ in 0..iters {
        pc.fill(0.0);
        psoft::linalg::matmul_acc_slice(&pa, &pb, &mut pc);
    }
    let pool_mm_ns = sw.secs() * 1e9 / iters as f64;
    let pool_speedup = seed_mm_ns / pool_mm_ns.max(1.0);

    println!(
        "step {:.3} ms ({steps_per_sec:.2} steps/s) — fwd {:.3} ms, bwd {:.3} ms, adamw {:.3} ms; \
         pool misses after warmup: {pool_misses_after_warmup}; thread spawns: {thread_spawns}; \
         pool speedup over seed kernel: {pool_speedup:.2}x; peak RSS {:.1} MiB",
        step_ns / 1e6,
        fwd_ns / 1e6,
        backward_ns / 1e6,
        optimizer_ns / 1e6,
        rss as f64 / (1024.0 * 1024.0)
    );

    let json = format!(
        "{{\n  \"workload\": \"encoder_small psoft r32 all-modules, batch {bsz} x seq {seq}\",\n  \
         \"steps_measured\": {steps},\n  \"steps_per_sec\": {steps_per_sec:.3},\n  \
         \"ns_per_step\": {{\n    \"total\": {step_ns:.0},\n    \"forward_loss\": {fwd_ns:.0},\n    \
         \"backward\": {backward_ns:.0},\n    \"optimizer\": {optimizer_ns:.0}\n  }},\n  \
         \"workspace_pool_misses_after_warmup\": {pool_misses_after_warmup},\n  \
         \"thread_spawns_during_measurement\": {thread_spawns},\n  \
         \"pool_speedup_over_seed\": {pool_speedup:.3},\n  \
         \"peak_rss_bytes\": {rss}\n}}\n"
    );
    std::fs::write("BENCH_hotpath.json", &json).expect("write BENCH_hotpath.json");
    eprintln!("wrote BENCH_hotpath.json");
}

/// Substrate microbenches (the §Perf baselines).
fn micro_substrates() {
    println!("\n=== microbenches ===");
    let mut rng = Rng::new(91);
    let a = Mat::randn(256, 256, 1.0, &mut rng);
    let b = Mat::randn(256, 256, 1.0, &mut rng);
    let t_mm = time_ms(10, || {
        let _ = matmul(&a, &b);
    });
    let flops = 2.0 * 256f64.powi(3);
    println!("matmul 256³: {t_mm:.3} ms ({:.2} GFLOP/s)", flops / t_mm / 1e6);

    let q = psoft::linalg::skew_from_params(
        46,
        &(0..46 * 45 / 2).map(|i| 0.01 * ((i % 7) as f64 - 3.0)).collect::<Vec<_>>(),
    );
    let t_cn = time_ms(20, || {
        let _ = psoft::linalg::cayley_neumann(&q, 5);
    });
    println!("cayley_neumann r=46 K=5: {t_cn:.3} ms");

    let w = DMat::randn(128, 128, 1.0, &mut rng);
    let t_svd = time_ms(3, || {
        let _ = svd(&w);
    });
    println!("jacobi svd 128×128: {t_svd:.1} ms");
    write_csv(
        "perf_micro",
        "kernel,ms",
        &[
            format!("matmul256,{t_mm:.4}"),
            format!("cayley_neumann_r46,{t_cn:.4}"),
            format!("svd128,{t_svd:.3}"),
        ],
    );
}

/// Table 19: FP/BP wall-time of a single adapted linear layer per method,
/// plus its retained-activation accounting (floats/token).
fn table19_single_layer() {
    println!("\n=== Table 19 (sim): single linear layer FP/BP per method ===");
    let (d, n) = (192, 192);
    let tokens = if fast() { 64 } else { 512 };
    let mut rng = Rng::new(92);
    let w = Mat::randn(d, n, 1.0 / (d as f64).sqrt(), &mut rng);
    let x = Mat::randn(tokens, d, 1.0, &mut rng);
    let dy = Mat::randn(tokens, n, 1.0, &mut rng);
    let mut rows = Vec::new();
    for m in MethodKind::ALL {
        let rank = match m {
            MethodKind::Psoft => 32,
            MethodKind::LoraXs => 32,
            _ => 8,
        };
        let mut cfg = PeftConfig::new(m, rank);
        cfg.oft_block_size = 32;
        cfg.boft_b = 2;
        cfg.boft_m = 4;
        let adapter = build_adapter(&cfg, &w, &mut rng);
        let fp = time_ms(5, || {
            let _ = adapter.forward(&x);
        });
        let bp = time_ms(5, || {
            let _ = adapter.backward(&x, &dy);
        });
        let act = adapter.act_floats_per_token();
        println!("{:<10} FP={fp:>8.3} ms  BP={bp:>8.3} ms  act/token={act}", m.name());
        rows.push(format!("{},{fp:.4},{bp:.4},{act}", m.name()));
    }
    write_csv("table19_single_layer", "method,fp_ms,bp_ms,act_floats_per_token", &rows);
}

/// Table 20: full transformer-block FP+BP per method (native backend,
/// one train-step without the optimizer update isolated per layer count 1).
fn table20_block() {
    println!("\n=== Table 20 (sim): transformer block FP+BP per method ===");
    let mut cfg = bench_encoder();
    cfg.n_layers = 1;
    let bsz = if fast() { 4 } else { 16 };
    let seq = 24;
    let mut rows = Vec::new();
    for m in [
        MethodKind::Psoft,
        MethodKind::Lora,
        MethodKind::Dora,
        MethodKind::OftV2,
        MethodKind::Boft,
        MethodKind::Goft,
        MethodKind::LoraXs,
    ] {
        let rank = if m == MethodKind::Psoft || m == MethodKind::LoraXs { 32 } else { 8 };
        let mut p = PeftConfig::new(m, rank);
        p.modules = cfg.modules();
        p.boft_b = 2;
        p.boft_m = 4;
        let mut rng = Rng::new(93);
        let bb = psoft::model::Backbone::random(&cfg, &mut rng);
        let model = NativeModel::from_backbone(&bb, &p, &mut rng);
        let tokens: Vec<i32> = (0..bsz * seq).map(|_| rng.below(cfg.vocab_size) as i32).collect();
        let labels: Vec<usize> = (0..bsz).map(|b| (tokens[b * seq] as usize) % 2).collect();
        let batch = Batch {
            batch: bsz,
            seq,
            tokens,
            pad: vec![1.0; bsz * seq],
            target: Target::Class(labels),
        };
        let ms = time_ms(3, || {
            let _ = psoft::model::native::train_grads(&model, &batch, 0.0);
        });
        // Live activation accounting at this shape (batch×seq tokens).
        let extra_floats: usize = model
            .layers
            .iter()
            .flat_map(|l| &l.modules)
            .filter_map(|(_, op)| match op {
                psoft::model::ModuleOp::Adapted(a) => Some(a.act_floats_per_token()),
                _ => None,
            })
            .sum();
        let extra_mb = (extra_floats * bsz * seq * 4) as f64 / 1e6;
        println!(
            "{:<10} fwd+bwd = {ms:>8.2} ms   adapter-activations = {extra_mb:.3} MB",
            m.name()
        );
        rows.push(format!("{},{ms:.3},{extra_mb:.4}", m.name()));
    }
    write_csv("table20_block", "method,fwdbwd_ms,adapter_act_mb", &rows);
}

/// Tables 21/22: whole-model projected peaks at paper scale across
/// sequence lengths (DeBERTa) and batch sizes (ViT) — including the OOM
/// boundaries.
fn table21_22_model_memory() {
    println!("\n=== Tables 21/22: projected peak memory at paper scale ===");
    let mut rows = Vec::new();
    let deberta = PaperModel::deberta_v3_base().config();
    for s in [64usize, 128, 256] {
        for (label, m, r) in [
            ("goftv2", MethodKind::Goft, 1),
            ("boft", MethodKind::Boft, 1),
            ("psoft", MethodKind::Psoft, 46),
        ] {
            let mut p = PeftConfig::new(m, r);
            p.modules = deberta.modules();
            let mem = peak_memory_estimate(&deberta, &p, 64, s);
            println!("deberta s={s:<4} {label:<8} {:.1} GiB", mem / 1.074e9);
            rows.push(format!("deberta,{s},{label},{mem:.0}"));
        }
    }
    let vit = PaperModel::vit_b16().config();
    for b in [16usize, 32, 64] {
        for (label, m, r) in [
            ("goftv2", MethodKind::Goft, 1),
            ("boft", MethodKind::Boft, 1),
            ("psoft", MethodKind::Psoft, 46),
        ] {
            let mut p = PeftConfig::new(m, r);
            p.modules = vit.modules();
            let mem = peak_memory_estimate(&vit, &p, b, 197);
            let oom = psoft::memmodel::would_oom(mem, psoft::memmodel::RTX4090_BYTES);
            println!(
                "vit b={b:<3} {label:<8} {:.1} GiB {}",
                mem / 1.074e9,
                if oom { "OOM@24G" } else { "" }
            );
            rows.push(format!("vit,{b},{label},{mem:.0}"));
        }
    }
    // Paper boundary: GOFT OOMs at b=64 on ViT; PSOFT stays far below.
    let shape = ActShape { batch: 64, seq: 197, hidden: 768, heads: 12, ffn_mult: 4.0 };
    let _ = shape;
    write_csv("table21_22_memory", "model,shape,method,mem_bytes", &rows);
}

/// Fig 4b: end-to-end training-speed comparison (steps/sec per method on
/// the same workload).
fn fig4b_training_speed() {
    println!("\n=== Fig 4b (sim): training speed per method ===");
    let cfg: ModelConfig = bench_encoder();
    let bb = pretrained_backbone(&cfg, "enc", 200);
    let bsz = if fast() { 8 } else { 16 };
    let seq = 24;
    let steps = if fast() { 2 } else { 5 };
    let mut rows = Vec::new();
    for m in [
        MethodKind::Psoft,
        MethodKind::Lora,
        MethodKind::Dora,
        MethodKind::OftV2,
        MethodKind::Boft,
        MethodKind::Goft,
        MethodKind::QGoft,
    ] {
        let rank = if m == MethodKind::Psoft { 32 } else { 8 };
        let mut p = PeftConfig::new(m, rank);
        p.modules = cfg.modules();
        p.boft_b = 2;
        p.boft_m = 4;
        let mut rng = Rng::new(94);
        let model = NativeModel::from_backbone(&bb, &p, &mut rng);
        let mut be = NativeBackend::new(model);
        let tokens: Vec<i32> = (0..bsz * seq).map(|_| rng.below(cfg.vocab_size) as i32).collect();
        let labels: Vec<usize> = (0..bsz).map(|b| (tokens[b * seq] as usize) % 2).collect();
        let batch = Batch {
            batch: bsz,
            seq,
            tokens,
            pad: vec![1.0; bsz * seq],
            target: Target::Class(labels),
        };
        let hyper = Hyper::default();
        let mut ws = Workspace::new();
        let ms = time_ms(steps, || {
            be.train_step(&batch, &hyper, &mut ws).unwrap();
        });
        println!("{:<10} {:>8.2} ms/step ({:.2} steps/s)", m.name(), ms, 1000.0 / ms);
        rows.push(format!("{},{ms:.3}", m.name()));
    }
    write_csv("fig4b_training_speed", "method,ms_per_step", &rows);
}
