//! Geometry probe (Figs 9/10): fine-tune with strict vs relaxed PSOFT and
//! measure how the pairwise column angles of W_pri / W_pre move.
//!
//! ```bash
//! cargo run --release --example geometry_probe
//! ```

use psoft::config::{DataConfig, MethodKind, ModelConfig, ModuleKind, PeftConfig, TrainConfig};
use psoft::data::load_task;
use psoft::geometry::{angles_to_csv, geometry_deviation, hyperspherical_energy, pairwise_angles};
use psoft::model::{Backbone, NativeModel};
use psoft::runtime::NativeBackend;
use psoft::train::train;
use psoft::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::encoder_small();
    let mut rng = Rng::new(11);
    let backbone = Backbone::random(&cfg, &mut rng);
    let probe_layer = cfg.n_layers / 2;
    let w_pre = backbone.weight(probe_layer, ModuleKind::Q).clone();
    let k = 8; // first eight columns, as in Appendix K

    let mut dc = DataConfig::new("glue", "cola");
    dc.n_train = 200;
    dc.n_val = 64;
    dc.n_test = 64;
    dc.seq_len = 24;
    let task = load_task(&dc, cfg.vocab_size)?;
    let mut tc = TrainConfig::default();
    tc.epochs = 4;
    tc.batch_size = 32;
    tc.lr = 2e-3;
    tc.head_lr = 2e-3;

    std::fs::create_dir_all("reports")?;
    for (label, use_vectors) in [("strict", false), ("relaxed", true)] {
        let mut peft = PeftConfig::new(MethodKind::Psoft, 24);
        peft.modules = cfg.modules();
        peft.use_alpha = use_vectors;
        peft.use_beta = use_vectors;
        let mut rng = Rng::new(23);
        let model = NativeModel::from_backbone(&backbone, &peft, &mut rng);
        let mut be = NativeBackend::new(model);
        let report = train(&mut be, &task, &tc, 0.0)?;
        let merged = be.model.to_backbone();
        let w_final = merged.weight(probe_layer, ModuleKind::Q);
        let (d_angle, d_norm) = geometry_deviation(&w_pre, w_final, k);
        println!(
            "{label:<8} PSOFT: metric {:.1}, max|Δangle| {:.4}°, max relΔnorm {:.5}, defect {:.4}, HSE {:.4} -> {:.4}",
            report.test_metric,
            d_angle.to_degrees(),
            d_norm,
            be.model.orth_defect(),
            hyperspherical_energy(&w_pre, k),
            hyperspherical_energy(w_final, k),
        );
        std::fs::write(
            format!("reports/fig9_angles_{label}.csv"),
            angles_to_csv(&pairwise_angles(w_final, k)),
        )?;
    }
    std::fs::write("reports/fig9_angles_pre.csv", angles_to_csv(&pairwise_angles(&w_pre, k)))?;
    println!("wrote reports/fig9_angles_{{pre,strict,relaxed}}.csv");
    Ok(())
}
