//! Quickstart: fine-tune a small encoder on one GLUE-sim task with PSOFT.
//!
//! ```bash
//! cargo run --release --example quickstart            # native backend
//! cargo run --release --example quickstart -- --backend pjrt
//! ```
//!
//! The PJRT variant exercises the full three-layer stack: the train step
//! (fwd+bwd+AdamW) runs inside the AOT-compiled XLA artifact built by
//! `make artifacts`; Rust owns every buffer.

use psoft::config::{DataConfig, MethodKind, ModelConfig, PeftConfig, TrainConfig};
use psoft::data::load_task;
use psoft::model::{Backbone, NativeModel};
use psoft::runtime::{pjrt::PjrtBackend, Backend, NativeBackend};
use psoft::train::train;
use psoft::util::cli::Args;
use psoft::util::rng::Rng;
use psoft::util::stats::human_duration;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let backend_kind = args.get_or("backend", "native");

    // Model: the DeBERTa-sim encoder matching the `glue_cls_psoft_r46`
    // artifact in configs/artifacts_manifest.json.
    let cfg = ModelConfig {
        arch: psoft::config::Arch::Encoder,
        vocab_size: 512,
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_ff: 512,
        max_seq: 64,
        n_classes: 2,
    };
    let mut rng = Rng::new(42);
    let backbone = Backbone::random(&cfg, &mut rng);

    // PSOFT at the paper's encoder rank (Table 2: r = 46 on all linears).
    let mut peft = PeftConfig::new(MethodKind::Psoft, 46);
    peft.modules = cfg.modules();
    let model = NativeModel::from_backbone(&backbone, &peft, &mut rng);
    println!(
        "PSOFT r=46 on all linears: {} trainable adapter params (+{} head)",
        model.num_adapter_params(),
        model.num_trainable() - model.num_adapter_params()
    );

    let mut backend: Box<dyn Backend> = match backend_kind {
        "pjrt" => Box::new(PjrtBackend::from_artifact(
            Path::new("artifacts"),
            "glue_cls_psoft_r46",
            &model,
        )?),
        _ => Box::new(NativeBackend::new(model)),
    };

    // Task: SST-2-sim (planted token-valence sentiment).
    let mut dc = DataConfig::new("glue", "sst2");
    dc.n_train = 256;
    dc.n_val = 64;
    dc.n_test = 64;
    dc.seq_len = 32;
    let task = load_task(&dc, cfg.vocab_size)?;

    let mut tc = TrainConfig::default();
    tc.epochs = 5;
    tc.batch_size = 32;
    tc.lr = 2e-3;
    tc.head_lr = 2e-3;

    println!("fine-tuning sst2-sim on the `{}` backend…", backend.name());
    let report = train(backend.as_mut(), &task, &tc, 0.0)?;
    println!(
        "done in {} ({} steps): test accuracy {:.1}%  (val {:.1}%), loss {:.3} -> {:.3}",
        human_duration(report.wall_secs),
        report.steps,
        report.test_metric,
        report.val_metric,
        report.loss_curve.first().unwrap_or(&f64::NAN),
        report.final_loss,
    );
    Ok(())
}
