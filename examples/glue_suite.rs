//! GLUE-sim suite across PEFT methods — the Table 2 workflow as a library
//! consumer would run it: pretrain (or load) a backbone, build the job
//! grid, fan it over the coordinator, and print the paper-style table.
//!
//! ```bash
//! cargo run --release --example glue_suite -- --seeds 1,2 --epochs 3
//! ```

use psoft::config::{DataConfig, MethodKind, ModelConfig, PeftConfig, TrainConfig};
use psoft::coordinator::{aggregate, grid, report, DeviceBudget, SuiteRunner};
use psoft::data::suite_tasks;
use psoft::model::Backbone;
use psoft::util::cli::Args;
use psoft::util::rng::Rng;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let cfg = ModelConfig::encoder_small();
    let mut rng = Rng::new(42);
    let backbone = match args.get("backbone") {
        Some(p) => Backbone::load(std::path::Path::new(p))?,
        None => Backbone::random(&cfg, &mut rng),
    };

    let seeds: Vec<u64> = if args.get("seeds").is_some() {
        args.usize_list("seeds")?.into_iter().map(|s| s as u64).collect()
    } else {
        vec![1, 2]
    };

    let tasks: Vec<DataConfig> = suite_tasks("glue")
        .into_iter()
        .map(|t| {
            let mut d = DataConfig::new("glue", t);
            d.n_train = args.usize("n-train", 200).unwrap();
            d.n_val = 64;
            d.n_test = 64;
            d.seq_len = 24;
            d
        })
        .collect();

    let methods: Vec<(String, PeftConfig)> = [
        (MethodKind::Psoft, 46),
        (MethodKind::Lora, 8),
        (MethodKind::Pissa, 8),
        (MethodKind::LoraXs, 46),
        (MethodKind::OftV2, 0),
        (MethodKind::Dora, 8),
    ]
    .into_iter()
    .map(|(m, r)| {
        let mut p = PeftConfig::new(m, r.max(1));
        p.modules = backbone.cfg.modules();
        p.oft_block_size = 32;
        (format!("{}_r{}", m.name(), r.max(1)), p)
    })
    .collect();

    let mut tc = TrainConfig::default();
    tc.epochs = args.usize("epochs", 3)?;
    tc.batch_size = 32;
    tc.lr = 2e-3;
    tc.head_lr = 2e-3;

    let jobs = grid(&tasks, &methods, &tc, &seeds);
    println!("running {} jobs…", jobs.len());
    let runner = Arc::new(SuiteRunner::new(backbone, DeviceBudget::unlimited()));
    let results = runner.run_all(jobs, psoft::util::threadpool::default_parallelism());
    let cells = aggregate(&results);
    let table = report::Table::from_cells("GLUE-sim (Table 2 workflow)", &suite_tasks("glue"), &cells);
    println!("{}", table.to_markdown());
    report::write_bundle(std::path::Path::new("reports"), "example_glue_suite", &table)?;
    Ok(())
}
