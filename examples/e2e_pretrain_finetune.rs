//! End-to-end driver (DESIGN.md §End-to-end validation): pretrain a small
//! decoder LM on the pretext corpus for a few hundred steps, save the
//! checkpoint, then PSOFT-fine-tune it on GSM-8K-sim and compare against
//! LoRA at a matched parameter budget — logging both loss curves.
//!
//! ```bash
//! cargo run --release --example e2e_pretrain_finetune
//! cargo run --release --example e2e_pretrain_finetune -- --pretrain-steps 300
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use psoft::config::{Arch, DataConfig, MethodKind, ModelConfig, PeftConfig, TrainConfig};
use psoft::data::load_task;
use psoft::memmodel::params::psoft_rank_for_budget;
use psoft::model::{Backbone, NativeModel};
use psoft::runtime::{Backend, Hyper, NativeBackend};
use psoft::train::train;
use psoft::util::cli::Args;
use psoft::util::rng::Rng;
use psoft::util::stats::{human_duration, Stopwatch};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let pretrain_steps = args.usize("pretrain-steps", 200)?;
    let seq = 48;

    // A ~6M-param decoder (the largest comfortably CPU-trainable here;
    // scale substitution documented in DESIGN.md §4).
    let cfg = ModelConfig {
        arch: Arch::Decoder,
        vocab_size: 512,
        d_model: 192,
        n_layers: 4,
        n_heads: 4,
        d_ff: 512,
        max_seq: 96,
        n_classes: 0,
    };
    println!("backbone: {} params", cfg.backbone_params());

    // ---- Phase 1: pretraining on the pretext corpus -----------------------
    let mut rng = Rng::new(7);
    let model = NativeModel::for_pretraining(&cfg, &mut rng);
    let mut backend = NativeBackend::new(model);
    let mut dc = DataConfig::new("pretext", "corpus");
    dc.n_train = pretrain_steps * 16;
    dc.n_val = 1;
    dc.n_test = 1;
    dc.seq_len = seq;
    let corpus = load_task(&dc, cfg.vocab_size)?;
    let batches = corpus.batches(&corpus.train, 16, &mut rng);
    let hyper = Hyper { lr: 3e-3, head_lr: 3e-3, ..Default::default() };
    let sw = Stopwatch::start();
    let mut pre_curve = Vec::new();
    for (i, b) in batches.iter().take(pretrain_steps).enumerate() {
        let out = backend.train_step(b, &hyper)?;
        pre_curve.push(out.loss);
        if (i + 1) % 50 == 0 {
            println!("  pretrain step {:>4}: loss {:.4}", i + 1, out.loss);
        }
    }
    println!(
        "pretraining: {} steps in {}, loss {:.3} -> {:.3}",
        pre_curve.len(),
        human_duration(sw.secs()),
        pre_curve[0],
        pre_curve.last().unwrap()
    );
    let backbone: Backbone = backend.model.to_backbone();
    std::fs::create_dir_all("checkpoints")?;
    backbone.save(std::path::Path::new("checkpoints/e2e_decoder.bin"))?;

    // ---- Phase 2: PEFT fine-tuning on GSM-8K-sim --------------------------
    let mut task_cfg = DataConfig::new("mathqa", "gsm8k");
    task_cfg.n_train = 512;
    task_cfg.n_val = 128;
    task_cfg.n_test = 128;
    task_cfg.seq_len = seq;
    let task = load_task(&task_cfg, cfg.vocab_size)?;

    let mut tc = TrainConfig::default();
    tc.epochs = 4;
    tc.batch_size = 16;
    tc.lr = 2e-3;
    tc.head_lr = 2e-3;

    // Budget-matched ranks (paper §4.1): LoRA r=4 vs PSOFT r=√M.
    let lora_rank = 4;
    let (d, n) = (cfg.d_model, cfg.d_model);
    let psoft_rank = psoft_rank_for_budget(lora_rank, d, n).min(d);
    println!("\nbudget match: lora r={lora_rank} vs psoft r={psoft_rank}");

    let mut results = Vec::new();
    for (method, rank) in [(MethodKind::Lora, lora_rank), (MethodKind::Psoft, psoft_rank)] {
        let mut peft = PeftConfig::new(method, rank);
        peft.modules = cfg.modules();
        let mut rng = Rng::new(99);
        let model = NativeModel::from_backbone(&backbone, &peft, &mut rng);
        let params = model.num_adapter_params();
        let mut be = NativeBackend::new(model);
        let sw = Stopwatch::start();
        let report = train(&mut be, &task, &tc, 0.0)?;
        println!(
            "{:<6} r={:<3} params={:<8} steps={} wall={} EM={:.1}% loss {:.3} -> {:.3}",
            method.name(),
            rank,
            params,
            report.steps,
            human_duration(sw.secs()),
            report.test_metric,
            report.loss_curve.first().unwrap_or(&f64::NAN),
            report.final_loss
        );
        results.push((method.name(), report));
    }

    // Loss curves to CSV for EXPERIMENTS.md.
    std::fs::create_dir_all("reports")?;
    let mut csv = String::from("step,pretrain");
    for (name, _) in &results {
        csv.push_str(&format!(",{name}"));
    }
    csv.push('\n');
    let max_len = results.iter().map(|(_, r)| r.loss_curve.len()).max().unwrap_or(0);
    for i in 0..pre_curve.len().max(max_len) {
        csv.push_str(&format!("{i}"));
        csv.push_str(&pre_curve.get(i).map(|l| format!(",{l:.5}")).unwrap_or(",".into()));
        for (_, r) in &results {
            csv.push_str(&r.loss_curve.get(i).map(|l| format!(",{l:.5}")).unwrap_or(",".into()));
        }
        csv.push('\n');
    }
    std::fs::write("reports/e2e_loss_curves.csv", csv)?;
    println!("\nwrote reports/e2e_loss_curves.csv; checkpoint at checkpoints/e2e_decoder.bin");
    Ok(())
}
