//! CI perf-regression gate over `BENCH_*.json` baselines.
//!
//! Usage (from `rust/`, after a bench run has written fresh JSON):
//!
//! ```text
//! bench_gate --baseline ../BENCH_hotpath.json --current BENCH_hotpath.json \
//!            --key steps_per_sec --max-regression 0.15
//! ```
//!
//! The key is a dot-path into the JSON (`ns_per_step.total`, `configs.2.
//! reqs_per_sec`, …). For higher-is-better metrics (the default) the gate
//! fails when `current < baseline × (1 − max_regression)`; with
//! `--lower-is-better` it fails when `current > baseline × (1 +
//! max_regression)`. Improvements always pass — the committed baseline is
//! a floor, refreshed by re-running the bench and committing its output.
//!
//! Exit codes: 0 pass, 1 regression, 2 usage/IO error.

use psoft::util::json::Json;

fn lookup<'a>(mut v: &'a Json, path: &str) -> Option<f64> {
    for part in path.split('.') {
        v = match part.parse::<usize>() {
            Ok(i) => v.at(i),
            Err(_) => v.get(part),
        };
    }
    v.as_f64()
}

fn load(path: &str) -> Result<Json, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("parsing {path}: {e}"))
}

struct Opts {
    baseline: String,
    current: String,
    key: String,
    max_regression: f64,
    lower_is_better: bool,
}

fn parse_args() -> Result<Opts, String> {
    let mut args = std::env::args().skip(1);
    let mut baseline = None;
    let mut current = None;
    let mut key = "steps_per_sec".to_string();
    let mut max_regression = 0.15;
    let mut lower_is_better = false;
    while let Some(arg) = args.next() {
        let mut take = |what: &str| args.next().ok_or(format!("{what} expects a value"));
        match arg.as_str() {
            "--baseline" => baseline = Some(take("--baseline")?),
            "--current" => current = Some(take("--current")?),
            "--key" => key = take("--key")?,
            "--max-regression" => {
                max_regression = take("--max-regression")?
                    .parse()
                    .map_err(|_| "--max-regression expects a number".to_string())?;
            }
            "--lower-is-better" => lower_is_better = true,
            other => return Err(format!("unknown argument {other:?}")),
        }
    }
    Ok(Opts {
        baseline: baseline.ok_or("--baseline is required")?,
        current: current.ok_or("--current is required")?,
        key,
        max_regression,
        lower_is_better,
    })
}

fn main() {
    std::process::exit(run());
}

fn run() -> i32 {
    let opts = match parse_args() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("bench_gate: {e}");
            return 2;
        }
    };
    let (bjson, cjson) = match (load(&opts.baseline), load(&opts.current)) {
        (Ok(b), Ok(c)) => (b, c),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("bench_gate: {e}");
            return 2;
        }
    };
    let Some(base) = lookup(&bjson, &opts.key) else {
        eprintln!("bench_gate: key {:?} missing in {}", opts.key, opts.baseline);
        return 2;
    };
    let Some(cur) = lookup(&cjson, &opts.key) else {
        eprintln!("bench_gate: key {:?} missing in {}", opts.key, opts.current);
        return 2;
    };
    let tol = opts.max_regression;
    let pass = if opts.lower_is_better {
        cur <= base * (1.0 + tol)
    } else {
        cur >= base * (1.0 - tol)
    };
    let verdict = if pass { "PASS" } else { "FAIL" };
    println!(
        "bench_gate: {key}: baseline {base:.4}, current {cur:.4} \
         (allowed regression {pct:.0}%, {dir}) -> {verdict}",
        key = opts.key,
        pct = tol * 100.0,
        dir = if opts.lower_is_better { "lower-is-better" } else { "higher-is-better" },
    );
    if pass {
        0
    } else {
        eprintln!(
            "bench_gate: perf regression on {:?} — if intentional, refresh the baseline by \
             re-running the bench and committing its {} output",
            opts.key, opts.current
        );
        1
    }
}
