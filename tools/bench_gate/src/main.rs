//! CI perf-regression gate over `BENCH_*.json` / `ARTIFACT_SIZES.json`
//! baselines.
//!
//! Usage (from `rust/`, after a bench run has written fresh JSON):
//!
//! ```text
//! bench_gate --baseline ../BENCH_hotpath.json --current BENCH_hotpath.json \
//!            --key steps_per_sec --max-regression 0.15
//! ```
//!
//! The key is a dot-path into the JSON (`ns_per_step.total`, `configs.2.
//! reqs_per_sec`, …). For higher-is-better metrics (the default) the gate
//! fails when `current < baseline × (1 − max_regression)`; with
//! `--lower-is-better` it fails when `current > baseline × (1 +
//! max_regression)`. Improvements always pass — the committed baseline is
//! a floor, refreshed by re-running the bench and committing its output.
//!
//! `--foreach <obj-path>` runs the same check once per entry of the
//! object at `obj-path` in the *baseline*, with `--key` interpreted
//! relative to each entry. This is how the artifact-size gate checks
//! every PEFT method in one invocation:
//!
//! ```text
//! bench_gate --baseline ../ARTIFACT_SIZES.json --current artifact_sizes.json \
//!            --foreach methods --key bytes_per_param \
//!            --lower-is-better --max-regression 0.0
//! ```
//!
//! fails if any method's artifact bytes-per-parameter exceeds its
//! committed ceiling (format bloat: f64 storage, duplicated tensors, …).
//!
//! With `--key .` (or an empty `--key`) under `--foreach`, each entry *is*
//! the value — for flat phase maps like `ns_per_step: {total, forward_loss,
//! …}`:
//!
//! ```text
//! bench_gate --baseline ../BENCH_hotpath.json --current BENCH_hotpath.json \
//!            --foreach ns_per_step --key . --lower-is-better --max-regression 0.5
//! ```
//!
//! gates every phase floor in one invocation and reports a per-phase
//! verdict line with the signed delta.
//!
//! `--update-baselines` closes the refresh loop: instead of gating, it
//! rewrites the committed baseline file from the fresh run —
//!
//! ```text
//! bench_gate --baseline ../BENCH_decode.json --current BENCH_decode.json \
//!            --update-baselines
//! ```
//!
//! validates that the current output parses, then copies it over the
//! baseline path verbatim (commit the result). This is how the
//! provisional conservative floors get replaced with measured numbers on
//! a real machine.
//!
//! Exit codes: 0 pass, 1 regression, 2 usage/IO error.

use psoft::util::json::Json;

fn lookup<'a>(mut v: &'a Json, path: &str) -> Option<f64> {
    for part in path.split('.') {
        v = match part.parse::<usize>() {
            Ok(i) => v.at(i),
            Err(_) => v.get(part),
        };
    }
    v.as_f64()
}

fn lookup_node<'a>(mut v: &'a Json, path: &str) -> &'a Json {
    for part in path.split('.') {
        v = match part.parse::<usize>() {
            Ok(i) => v.at(i),
            Err(_) => v.get(part),
        };
    }
    v
}

fn load(path: &str) -> Result<Json, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("parsing {path}: {e}"))
}

struct Opts {
    baseline: String,
    current: String,
    key: String,
    max_regression: f64,
    lower_is_better: bool,
    foreach: Option<String>,
    update_baselines: bool,
}

fn parse_args() -> Result<Opts, String> {
    let mut args = std::env::args().skip(1);
    let mut baseline = None;
    let mut current = None;
    let mut key = "steps_per_sec".to_string();
    let mut max_regression = 0.15;
    let mut lower_is_better = false;
    let mut foreach = None;
    let mut update_baselines = false;
    while let Some(arg) = args.next() {
        let mut take = |what: &str| args.next().ok_or(format!("{what} expects a value"));
        match arg.as_str() {
            "--baseline" => baseline = Some(take("--baseline")?),
            "--current" => current = Some(take("--current")?),
            "--key" => key = take("--key")?,
            "--max-regression" => {
                max_regression = take("--max-regression")?
                    .parse()
                    .map_err(|_| "--max-regression expects a number".to_string())?;
            }
            "--lower-is-better" => lower_is_better = true,
            "--foreach" => foreach = Some(take("--foreach")?),
            "--update-baselines" => update_baselines = true,
            other => return Err(format!("unknown argument {other:?}")),
        }
    }
    Ok(Opts {
        baseline: baseline.ok_or("--baseline is required")?,
        current: current.ok_or("--current is required")?,
        key,
        max_regression,
        lower_is_better,
        foreach,
        update_baselines,
    })
}

/// One comparison; prints its verdict line and returns pass/fail.
fn check(key: &str, base: f64, cur: f64, tol: f64, lower_is_better: bool) -> bool {
    let pass = if lower_is_better {
        cur <= base * (1.0 + tol)
    } else {
        cur >= base * (1.0 - tol)
    };
    let verdict = if pass { "PASS" } else { "FAIL" };
    // Signed change relative to baseline; for lower-is-better metrics a
    // positive delta is the regression direction.
    let delta = if base != 0.0 { (cur - base) / base * 100.0 } else { 0.0 };
    println!(
        "bench_gate: {key}: baseline {base:.4} -> current {cur:.4} ({delta:+.1}%) \
         (allowed regression {pct:.0}%, {dir}) -> {verdict}",
        pct = tol * 100.0,
        dir = if lower_is_better { "lower-is-better" } else { "higher-is-better" },
    );
    pass
}

fn main() {
    std::process::exit(run());
}

fn run() -> i32 {
    let opts = match parse_args() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("bench_gate: {e}");
            return 2;
        }
    };
    if opts.update_baselines {
        // Refresh mode: validate the fresh output parses, then rewrite
        // the committed baseline verbatim. No gating.
        if let Err(e) = load(&opts.current) {
            eprintln!("bench_gate: {e}");
            return 2;
        }
        return match std::fs::copy(&opts.current, &opts.baseline) {
            Ok(bytes) => {
                println!(
                    "bench_gate: baseline {} refreshed from {} ({bytes} bytes) — commit it",
                    opts.baseline, opts.current
                );
                0
            }
            Err(e) => {
                eprintln!("bench_gate: copying {} over {}: {e}", opts.current, opts.baseline);
                2
            }
        };
    }

    let (bjson, cjson) = match (load(&opts.baseline), load(&opts.current)) {
        (Ok(b), Ok(c)) => (b, c),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("bench_gate: {e}");
            return 2;
        }
    };

    // Collect the (display key, lookup path) pairs to check: one for the
    // plain mode, one per baseline entry under --foreach.
    let paths: Vec<String> = match &opts.foreach {
        None => vec![opts.key.clone()],
        Some(obj_path) => {
            let Some(obj) = lookup_node(&bjson, obj_path).as_obj() else {
                eprintln!(
                    "bench_gate: --foreach path {obj_path:?} is not an object in {}",
                    opts.baseline
                );
                return 2;
            };
            if obj.is_empty() {
                eprintln!("bench_gate: --foreach object {obj_path:?} is empty");
                return 2;
            }
            // Entries present in the current output but absent from the
            // committed baseline would otherwise skip the gate entirely
            // (e.g. a newly added method with a bloated encoding).
            if let Some(cobj) = lookup_node(&cjson, obj_path).as_obj() {
                let extra: Vec<&String> =
                    cobj.keys().filter(|k| !obj.contains_key(*k)).collect();
                if !extra.is_empty() {
                    eprintln!(
                        "bench_gate: {obj_path} entries {extra:?} exist in {} but not in the \
                         baseline {} — add committed expectations for them",
                        opts.current, opts.baseline
                    );
                    return 1;
                }
            }
            // `--key .` (or empty): the entry itself is the value — for
            // flat maps of metric -> number (e.g. ns_per_step phases).
            if opts.key.is_empty() || opts.key == "." {
                obj.keys().map(|k| format!("{obj_path}.{k}")).collect()
            } else {
                obj.keys().map(|k| format!("{obj_path}.{k}.{}", opts.key)).collect()
            }
        }
    };

    let mut all_pass = true;
    for path in &paths {
        let Some(base) = lookup(&bjson, path) else {
            eprintln!("bench_gate: key {path:?} missing in {}", opts.baseline);
            return 2;
        };
        let Some(cur) = lookup(&cjson, path) else {
            eprintln!("bench_gate: key {path:?} missing in {}", opts.current);
            return 2;
        };
        all_pass &= check(path, base, cur, opts.max_regression, opts.lower_is_better);
    }
    if all_pass {
        0
    } else {
        eprintln!(
            "bench_gate: regression detected — if intentional, refresh the baseline by \
             re-running the generator and committing its {} output",
            opts.current
        );
        1
    }
}
